//! Wire protocol: length-prefixed, checksummed tagged frames over TCP.
//!
//! ```text
//! frame := tag:u8 len:u64le sum:u32le payload[len]
//! ```
//!
//! `sum` is a word-folded checksum of the tag and payload
//! ([`frame_sum`]): a frame corrupted in flight (or by a buggy peer)
//! surfaces as a typed [`NetError::Malformed`] at [`read_frame`] instead
//! of silently poisoning vocabularies or result rows downstream — the
//! property the chaos suite's corrupt-frame faults pin.
//!
//! Leader → worker, two-pass protocol: `Job`, `Pass1Chunk`*, `Pass1End`,
//! `Pass2Chunk`*, `Pass2End`. Fused single-pass protocol: `Job`,
//! `FusedChunk`*, `FusedEnd` — the dataset crosses the wire **once**,
//! appearance indices are assigned on the fly and results stream back
//! while the input is still arriving. Worker → leader: `ResultChunk`*
//! (packed processed rows), `ResultEnd` (stats). The strategy is not in
//! the job header — the first data frame picks the protocol, so old
//! leaders keep working and the cluster leader-merge path simply keeps
//! sending pass frames.
//!
//! I/O errors are classified into the [`NetError`] taxonomy at this
//! layer, so every caller up the stack (leader, cluster retry loop,
//! serve client) can distinguish retryable failures (timeout, peer
//! gone, overload) from fatal ones without string matching.

use crate::data::row::{ProcessedColumns, ProcessedRow};
use crate::data::Schema;
use crate::decode::{ErrorBudget, ErrorConfig, ErrorPolicy};
use crate::ops::{Modulus, PipelineSpec};
use crate::Result;
use std::io::{Read, Write};

use super::stream::WireFormat;

// ---------------------------------------------------------------------
// Typed error taxonomy
// ---------------------------------------------------------------------

/// Typed network/cluster failure taxonomy. Every failure on the net
/// paths is classified into one of these variants (carried inside
/// `anyhow::Error`; recover it with [`NetError::of`]), replacing the
/// old ad-hoc `bail!` strings so callers can tell retryable conditions
/// from fatal ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// An I/O deadline expired: a socket read/write timed out, or the
    /// per-job wall-clock budget ran out.
    Timeout { what: String },
    /// The peer vanished: connection refused/reset/aborted, broken
    /// pipe, or an unexpected EOF mid-frame.
    PeerGone { what: String },
    /// The bytes on the wire are wrong: unknown tag, frame over the
    /// size cap, checksum mismatch, or a payload that fails to decode.
    Malformed { what: String },
    /// The serving worker's admission control refused the request;
    /// retry with backoff.
    Overloaded,
    /// The worker executed the session and reported an application
    /// error (its `ErrorReply` message is in `reason`).
    JobFailed { worker: String, reason: String },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout { what } => write!(f, "timeout: {what}"),
            NetError::PeerGone { what } => write!(f, "peer gone: {what}"),
            NetError::Malformed { what } => write!(f, "malformed: {what}"),
            NetError::Overloaded => write!(f, "overloaded: admission control refused the request"),
            NetError::JobFailed { worker, reason } => {
                write!(f, "job failed on worker {worker}: {reason}")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl NetError {
    /// Recover the typed error from an `anyhow::Error` chain (context
    /// layers added with `.context(...)` are looked through).
    pub fn of(err: &anyhow::Error) -> Option<&NetError> {
        err.downcast_ref::<NetError>()
    }

    /// Whether the *same* operation against the *same* peer is worth
    /// retrying. Note the cluster re-dispatches a failed shard to a
    /// *different* worker, which can also cure `Malformed`/`JobFailed`
    /// caused by one sick node — its retry loop is deliberately broader
    /// than this predicate.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            NetError::Timeout { .. } | NetError::PeerGone { .. } | NetError::Overloaded
        )
    }

    /// Classify an I/O error from a socket operation.
    pub fn from_io(what: &str, e: std::io::Error) -> anyhow::Error {
        use std::io::ErrorKind as K;
        let err = match e.kind() {
            K::TimedOut | K::WouldBlock => NetError::Timeout { what: format!("{what}: {e}") },
            K::UnexpectedEof
            | K::ConnectionReset
            | K::ConnectionAborted
            | K::ConnectionRefused
            | K::BrokenPipe
            | K::NotConnected => NetError::PeerGone { what: format!("{what}: {e}") },
            _ => return anyhow::Error::new(e).context(what.to_string()),
        };
        anyhow::Error::new(err)
    }
}

/// Frame tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    Job = 1,
    Pass1Chunk = 2,
    Pass1End = 3,
    Pass2Chunk = 4,
    Pass2End = 5,
    ResultChunk = 6,
    ResultEnd = 7,
    /// Leader → worker (cluster mode, after Pass1End): request the
    /// worker's sub-vocabularies for the global merge.
    VocabSync = 8,
    /// Worker → leader: sub-vocabulary keys in appearance order.
    VocabDump = 9,
    /// Leader → worker: the merged global vocabularies to apply in pass 2.
    VocabLoad = 10,
    /// Leader → worker (fused single-pass protocol): a raw chunk to
    /// observe *and* process in one scan.
    FusedChunk = 11,
    /// Leader → worker: end of the fused stream.
    FusedEnd = 12,
    /// Client → worker, first frame of the serving protocol: a frozen
    /// artifact plus miss policy and admission settings
    /// ([`crate::net::serve::ServeJob`]).
    ServeJob = 13,
    /// Client → worker: one small-batch request
    /// (`req_id:u64` + raw rows in the session's wire format).
    ServeRequest = 14,
    /// Worker → client: the response to one request
    /// ([`crate::net::serve::ServeResponse`]).
    ServeResponse = 15,
    /// Client → worker: end of the serving session.
    ServeEnd = 16,
    /// Worker → client, final frame of a serving session: aggregate
    /// latency/miss statistics ([`crate::net::serve::ServeReport`]).
    ServeReport = 17,
    /// Worker → peer: a fatal protocol/session error, carried as a
    /// UTF-8 message just before the worker closes the connection — so
    /// a malformed stream diagnoses itself instead of surfacing as a
    /// bare hangup on the other side.
    ErrorReply = 18,
    /// First frame of a service session ([`ServiceOpen`]): either the
    /// dispatcher joining a worker to a job (role `Dispatch`), a worker
    /// opening a key-forwarding session to a column owner (role
    /// `Keys`), or the worker's join acknowledgement (role `Ack`).
    ServiceHello = 19,
    /// Dispatcher → worker: split metadata ([`SplitAssign`]). The
    /// split's raw bytes follow as `FusedChunk`* + `FusedEnd` on the
    /// same session, so the worker decodes while the split streams in.
    SplitAssign = 20,
    /// Non-owner → owner: one split's unique raw keys for one
    /// vocabulary column, in in-split appearance order ([`KeyBatch`]).
    KeyBatch = 21,
    /// Owner → non-owner: the globally-assigned indices for a
    /// [`KeyBatch`], same order ([`IndexBatch`]).
    IndexBatch = 22,
    /// Worker → dispatcher: terminal status of one split
    /// ([`SplitDone`]). Dispatcher → worker with `seq == u64::MAX`
    /// doubles as the clean end-of-job marker.
    SplitDone = 23,
    /// Worker → dispatcher: one split's `(keys, indices)` vocabulary
    /// delta for one column ([`VocabDelta`]), sent before `SplitDone`
    /// so the dispatcher's mirror fold is race-free with completion.
    VocabDelta = 24,
    /// Dispatcher → worker: seed a column owner's sequencer with the
    /// mirror's fold prefix after an ownership transfer ([`OwnerSeed`]).
    OwnerSeed = 25,
}

impl Tag {
    pub fn from_u8(v: u8) -> Result<Tag> {
        Ok(match v {
            1 => Tag::Job,
            2 => Tag::Pass1Chunk,
            3 => Tag::Pass1End,
            4 => Tag::Pass2Chunk,
            5 => Tag::Pass2End,
            6 => Tag::ResultChunk,
            7 => Tag::ResultEnd,
            8 => Tag::VocabSync,
            9 => Tag::VocabDump,
            10 => Tag::VocabLoad,
            11 => Tag::FusedChunk,
            12 => Tag::FusedEnd,
            13 => Tag::ServeJob,
            14 => Tag::ServeRequest,
            15 => Tag::ServeResponse,
            16 => Tag::ServeEnd,
            17 => Tag::ServeReport,
            18 => Tag::ErrorReply,
            19 => Tag::ServiceHello,
            20 => Tag::SplitAssign,
            21 => Tag::KeyBatch,
            22 => Tag::IndexBatch,
            23 => Tag::SplitDone,
            24 => Tag::VocabDelta,
            25 => Tag::OwnerSeed,
            other => anyhow::bail!("unknown frame tag {other}"),
        })
    }
}

/// Encode per-column vocabulary keys: `ncols:u32 (len:u32 keys:u32*)*`.
pub fn pack_vocabs(cols: &[Vec<u32>]) -> Vec<u8> {
    let total: usize = cols.iter().map(|c| c.len()).sum();
    let mut out = Vec::with_capacity(4 + cols.len() * 4 + total * 4);
    out.extend_from_slice(&(cols.len() as u32).to_le_bytes());
    for col in cols {
        out.extend_from_slice(&(col.len() as u32).to_le_bytes());
        for &k in col {
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out
}

/// Decode [`pack_vocabs`] output.
pub fn unpack_vocabs(buf: &[u8]) -> Result<Vec<Vec<u32>>> {
    let rd_u32 = |at: usize| -> Result<u32> {
        let s = buf
            .get(at..at + 4)
            .ok_or_else(|| anyhow::anyhow!("vocab frame truncated at {at}"))?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    };
    let ncols = rd_u32(0)? as usize;
    anyhow::ensure!(ncols <= 4096, "unreasonable column count {ncols}");
    let mut cols = Vec::with_capacity(ncols);
    let mut at = 4;
    for _ in 0..ncols {
        let len = rd_u32(at)? as usize;
        at += 4;
        // Bound the reservation by the bytes actually present: a
        // malicious length field must produce a truncation error, not a
        // multi-gigabyte allocation.
        anyhow::ensure!(
            buf.len().saturating_sub(at) / 4 >= len,
            "vocab frame truncated: column claims {len} keys"
        );
        let mut col = Vec::with_capacity(len);
        for _ in 0..len {
            col.push(rd_u32(at)?);
            at += 4;
        }
        cols.push(col);
    }
    anyhow::ensure!(at == buf.len(), "trailing bytes in vocab frame");
    Ok(cols)
}

/// Bytes before the payload: `tag:u8 len:u64le sum:u32le`.
pub const FRAME_HEADER_BYTES: usize = 1 + 8 + 4;

/// Hard cap on a single frame's payload, enforced on read.
pub const MAX_FRAME: u64 = 1 << 30;

/// Word-folded checksum over tag + payload (xorshift-style mix per
/// 8-byte word — one multiply per 8 bytes, not per byte, so checking
/// never rivals the decode itself). Not cryptographic; it exists to
/// turn in-flight corruption into a typed [`NetError::Malformed`].
pub fn frame_sum(tag: u8, payload: &[u8]) -> u32 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15 ^ ((payload.len() as u64) << 8) ^ tag as u64;
    let mut chunks = payload.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
        h = (h ^ w).wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(23);
    }
    let rest = chunks.remainder();
    if !rest.is_empty() {
        let mut w = [0u8; 8];
        w[..rest.len()].copy_from_slice(rest);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(0x2545_F491_4F6C_DD1D).rotate_left(23);
    }
    (h ^ (h >> 32)) as u32
}

/// Write one frame. I/O errors are classified into [`NetError`].
pub fn write_frame<W: Write>(w: &mut W, tag: Tag, payload: &[u8]) -> Result<()> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    header[0] = tag as u8;
    header[1..9].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    header[9..13].copy_from_slice(&frame_sum(tag as u8, payload).to_le_bytes());
    w.write_all(&header)
        .and_then(|()| w.write_all(payload))
        .map_err(|e| NetError::from_io("writing frame", e))?;
    Ok(())
}

/// Read one frame. Payload size is capped to keep a corrupt peer from
/// forcing a huge allocation; the checksum is verified before the
/// payload is handed to any decoder. Timeouts, hangups and corruption
/// all surface as typed [`NetError`]s.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(Tag, Vec<u8>)> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut header)
        .map_err(|e| NetError::from_io("reading frame header", e))?;
    let len = u64::from_le_bytes([
        header[1], header[2], header[3], header[4],
        header[5], header[6], header[7], header[8],
    ]);
    if len > MAX_FRAME {
        anyhow::bail!(NetError::Malformed {
            what: format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        });
    }
    let sum = u32::from_le_bytes([header[9], header[10], header[11], header[12]]);
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| NetError::from_io("reading frame payload", e))?;
    if frame_sum(header[0], &payload) != sum {
        anyhow::bail!(NetError::Malformed {
            what: format!("frame checksum mismatch (tag {}, {len} bytes)", header[0]),
        });
    }
    let tag = Tag::from_u8(header[0]).map_err(|e| {
        anyhow::Error::new(NetError::Malformed { what: e.to_string() })
    })?;
    Ok((tag, payload))
}

/// Pack a cluster worker's pass-1 shard dump: the rows it observed plus
/// its sub-vocabularies (`rows:u64 || pack_vocabs`). The row count lets
/// the leader verify the shard was observed *in full* — a dropped or
/// swallowed pass-1 frame shows up as a count mismatch and triggers a
/// re-dispatch instead of silently skewing the global merge.
pub fn pack_shard_dump(rows: u64, cols: &[Vec<u32>]) -> Vec<u8> {
    let mut out = rows.to_le_bytes().to_vec();
    out.extend_from_slice(&pack_vocabs(cols));
    out
}

/// Decode [`pack_shard_dump`] output.
pub fn unpack_shard_dump(buf: &[u8]) -> Result<(u64, Vec<Vec<u32>>)> {
    anyhow::ensure!(buf.len() >= 8, "shard dump truncated: {} bytes", buf.len());
    let rows = u64::from_le_bytes([
        buf[0], buf[1], buf[2], buf[3], buf[4], buf[5], buf[6], buf[7],
    ]);
    Ok((rows, unpack_vocabs(&buf[8..])?))
}

/// Job header: schema, wire format and the full per-column operator
/// spec. The spec crosses the wire in its canonical [`PipelineSpec`]
/// display form and is re-parsed (and therefore re-validated) on the
/// worker — `parse(display(spec)) == spec` is pinned by the spec
/// round-trip property test.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub schema: Schema,
    pub spec: PipelineSpec,
    pub format: WireFormat,
    /// Malformed-row containment the worker decodes under. Quarantine
    /// raw bytes never cross the wire — a worker given the quarantine
    /// policy contains like `skip` and reports the count; the side file
    /// is a single-node (leader-local) artifact.
    pub errors: ErrorConfig,
}

impl Job {
    /// The classic fixed-pipeline job: the paper's DLRM preset at one
    /// uniform vocabulary size (what the old modulus-only header could
    /// express).
    pub fn dlrm(schema: Schema, modulus: Modulus, format: WireFormat) -> Job {
        Job {
            schema,
            spec: PipelineSpec::dlrm(modulus.range),
            format,
            errors: ErrorConfig::default(),
        }
    }

    /// Frame layout: `num_dense:u32 num_sparse:u32 format:u8 policy:u8
    /// budget_tag:u8 budget:f64le detail_cap:u32 spec:utf8` (the spec
    /// takes the rest of the frame — frames are already
    /// length-prefixed).
    pub fn encode(&self) -> Vec<u8> {
        let spec = self.spec.to_string();
        let mut out = Vec::with_capacity(23 + spec.len());
        out.extend_from_slice(&(self.schema.num_dense as u32).to_le_bytes());
        out.extend_from_slice(&(self.schema.num_sparse as u32).to_le_bytes());
        out.push(match self.format {
            WireFormat::Utf8 => 0,
            WireFormat::Binary => 1,
        });
        out.push(self.errors.policy.as_u8());
        let (btag, bval) = self.errors.budget.to_wire();
        out.push(btag);
        out.extend_from_slice(&bval.to_le_bytes());
        out.extend_from_slice(&(self.errors.detail_cap as u32).to_le_bytes());
        out.extend_from_slice(spec.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Job> {
        anyhow::ensure!(buf.len() >= 23, "job frame must be >= 23 bytes, got {}", buf.len());
        let rd = |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
        let format = match buf[8] {
            0 => WireFormat::Utf8,
            1 => WireFormat::Binary,
            v => anyhow::bail!("bad wire format {v}"),
        };
        let policy = ErrorPolicy::from_u8(buf[9])
            .ok_or_else(|| anyhow::anyhow!("bad error policy byte {}", buf[9]))?;
        let bval = f64::from_le_bytes([
            buf[11], buf[12], buf[13], buf[14], buf[15], buf[16], buf[17], buf[18],
        ]);
        let budget = ErrorBudget::from_wire(buf[10], bval)
            .ok_or_else(|| anyhow::anyhow!("bad error budget tag {}", buf[10]))?;
        let detail_cap = rd(19) as usize;
        anyhow::ensure!(detail_cap >= 1, "job error detail cap must be >= 1");
        let spec = std::str::from_utf8(&buf[23..])
            .map_err(|e| anyhow::anyhow!("job spec is not UTF-8: {e}"))?;
        Ok(Job {
            schema: Schema::new(rd(0) as usize, rd(4) as usize),
            spec: PipelineSpec::parse(spec)?,
            format,
            errors: ErrorConfig { policy, budget, detail_cap },
        })
    }
}

/// Pack processed rows for a ResultChunk: per row
/// `label:i32 dense...:f32 sparse...:u32`, all little-endian.
pub fn pack_rows(rows: &[ProcessedRow], schema: Schema) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * schema.binary_row_bytes());
    for r in rows {
        out.extend_from_slice(&r.label.to_le_bytes());
        for &d in &r.dense {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for &s in &r.sparse {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
    out
}

/// Unpack a ResultChunk.
pub fn unpack_rows(buf: &[u8], schema: Schema) -> Result<Vec<ProcessedRow>> {
    let rb = schema.binary_row_bytes();
    anyhow::ensure!(buf.len() % rb == 0, "result chunk misaligned");
    let mut rows = Vec::with_capacity(buf.len() / rb);
    for chunk in buf.chunks_exact(rb) {
        let w = |i: usize| [chunk[4 * i], chunk[4 * i + 1], chunk[4 * i + 2], chunk[4 * i + 3]];
        let label = i32::from_le_bytes(w(0));
        let dense = (0..schema.num_dense)
            .map(|c| f32::from_le_bytes(w(1 + c)))
            .collect();
        let sparse = (0..schema.num_sparse)
            .map(|c| u32::from_le_bytes(w(1 + schema.num_dense + c)))
            .collect();
        rows.push(ProcessedRow { label, dense, sparse });
    }
    Ok(rows)
}

/// Pack a processed column block straight into the [`pack_rows`] wire
/// layout — same bytes, no intermediate [`ProcessedRow`] materialization
/// (the serving path packs every response, so the per-row allocation of
/// a `row()` round trip would be pure overhead).
pub fn pack_columns(cols: &ProcessedColumns, schema: Schema) -> Vec<u8> {
    let rows = cols.num_rows();
    let mut out = Vec::with_capacity(rows * schema.binary_row_bytes());
    for r in 0..rows {
        out.extend_from_slice(&cols.labels[r].to_le_bytes());
        for col in &cols.dense {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
        for col in &cols.sparse {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
    }
    out
}

/// Stats returned in ResultEnd. The containment counters let the
/// leader merge exact per-worker skip/quarantine totals into the
/// cluster report and verify every row was accounted for (kept,
/// skipped, or quarantined).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RunStats {
    pub rows: u64,
    pub vocab_entries: u64,
    /// Rows dropped under `on_error=skip`.
    pub rows_skipped: u64,
    /// Rows contained under `on_error=quarantine` (counters only — the
    /// raw bytes stay on the node that owns the quarantine file).
    pub rows_quarantined: u64,
    /// Illegal input bytes the decode skipped (zero-policy semantics).
    pub illegal_bytes: u64,
    /// Wall nanoseconds this worker spent decoding raw bytes.
    pub decode_ns: u64,
    /// Wall nanoseconds in the stateless per-column stage.
    pub stateless_ns: u64,
    /// Wall nanoseconds in the vocabulary stage (observe/apply fold,
    /// plus — on the service path — remote index waits and rewrites).
    pub vocab_ns: u64,
}

impl RunStats {
    /// Field-wise sum, for merging per-split / per-worker stats.
    /// `vocab_entries` saturates by addition too — the service layer
    /// overwrites it with the authoritative mirror total at the end.
    pub fn merge(&mut self, o: &RunStats) {
        self.rows += o.rows;
        self.vocab_entries += o.vocab_entries;
        self.rows_skipped += o.rows_skipped;
        self.rows_quarantined += o.rows_quarantined;
        self.illegal_bytes += o.illegal_bytes;
        self.decode_ns += o.decode_ns;
        self.stateless_ns += o.stateless_ns;
        self.vocab_ns += o.vocab_ns;
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        out.extend_from_slice(&self.rows.to_le_bytes());
        out.extend_from_slice(&self.vocab_entries.to_le_bytes());
        out.extend_from_slice(&self.rows_skipped.to_le_bytes());
        out.extend_from_slice(&self.rows_quarantined.to_le_bytes());
        out.extend_from_slice(&self.illegal_bytes.to_le_bytes());
        out.extend_from_slice(&self.decode_ns.to_le_bytes());
        out.extend_from_slice(&self.stateless_ns.to_le_bytes());
        out.extend_from_slice(&self.vocab_ns.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunStats> {
        anyhow::ensure!(buf.len() == 64, "stats frame must be 64 bytes");
        let rd = |i: usize| {
            u64::from_le_bytes([
                buf[i], buf[i + 1], buf[i + 2], buf[i + 3],
                buf[i + 4], buf[i + 5], buf[i + 6], buf[i + 7],
            ])
        };
        Ok(RunStats {
            rows: rd(0),
            vocab_entries: rd(8),
            rows_skipped: rd(16),
            rows_quarantined: rd(24),
            illegal_bytes: rd(32),
            decode_ns: rd(40),
            stateless_ns: rd(48),
            vocab_ns: rd(56),
        })
    }
}

// ---------------------------------------------------------------------
// Service protocol (disaggregated preprocessing service, PR 10)
// ---------------------------------------------------------------------
//
// Session shapes:
//
// Dispatch session (dispatcher → worker):
//   `ServiceHello{Dispatch}` → `ServiceHello{Ack}` ← then a stream of
//   `SplitAssign` + `FusedChunk`* + `FusedEnd` per split, `OwnerSeed`
//   after ownership transfers, and a final `SplitDone{seq: u64::MAX}`
//   end-of-job marker. The worker replies per split with `VocabDelta`*
//   (one per vocabulary column), `ResultChunk`* (payload prefixed with
//   the split's `seq:u64le` so a multiplexed reader can attribute
//   rows), and `SplitDone`.
//
// Key session (worker → worker, one per (job, owner) pair):
//   `ServiceHello{Keys}` → `ServiceHello{Ack}` ← then `KeyBatch` →
//   `IndexBatch` ← pairs. There is no `Pass1End → VocabLoad` barrier
//   anywhere on the service path: index assignment happens inside the
//   owner's per-column sequencer, in (split seq, in-split appearance)
//   order, while the rest of the cluster keeps streaming.

/// Reads a `u16`/`u32`/`u64` cursor over a payload with typed
/// truncation errors — the shared decoding substrate for the service
/// frames below (all little-endian, like the rest of the protocol).
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let s = self
            .buf
            .get(self.at..self.at + n)
            .ok_or_else(|| anyhow::anyhow!("{what}: frame truncated at byte {}", self.at))?;
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    /// A `count:u32`-prefixed vector of `u32`s, with the reservation
    /// bounded by the bytes actually present (hostile-length guard).
    fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.u32(what)? as usize;
        anyhow::ensure!(
            self.buf.len().saturating_sub(self.at) / 4 >= n,
            "{what}: frame truncated (claims {n} words)"
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32(what)?);
        }
        Ok(out)
    }

    fn done(&self, what: &str) -> Result<()> {
        anyhow::ensure!(self.at == self.buf.len(), "{what}: trailing bytes in frame");
        Ok(())
    }
}

/// Cap on the per-column owner table / peer list length, mirroring the
/// `unpack_vocabs` column cap: a hostile hello must fail fast, not
/// force a giant allocation.
const MAX_SERVICE_COLS: usize = 4096;

/// Dispatcher → worker join frame: everything the worker needs to take
/// part in one service job.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceHello {
    /// Dispatcher-chosen job identity; worker-side per-job state
    /// (column sequencers) is keyed by `(job_id, worker_id)` so
    /// concurrent jobs multiplex one worker pool without collisions.
    pub job_id: u64,
    /// This worker's id within the job (index into `peers`).
    pub worker_id: u16,
    /// Ownership epoch the hello's `owners` table belongs to.
    pub epoch: u32,
    /// Per-sparse-column owner worker id (hash partition).
    pub owners: Vec<u16>,
    /// Worker addresses by id, for opening key-forwarding sessions.
    pub peers: Vec<String>,
    /// Decode threads per split (0 = worker default).
    pub decode_threads: u16,
    pub job: Job,
}

/// Worker → owner join frame for a key-forwarding session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyHello {
    pub job_id: u64,
    /// The owner the session is addressed to (consistency check).
    pub owner_id: u16,
    pub requester_id: u16,
}

/// First frame of any service session, and its acknowledgement.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceOpen {
    Dispatch(ServiceHello),
    Keys(KeyHello),
    Ack { worker_id: u16 },
}

impl ServiceOpen {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            ServiceOpen::Dispatch(h) => {
                out.push(0);
                out.extend_from_slice(&h.job_id.to_le_bytes());
                out.extend_from_slice(&h.worker_id.to_le_bytes());
                out.extend_from_slice(&h.epoch.to_le_bytes());
                out.extend_from_slice(&h.decode_threads.to_le_bytes());
                out.extend_from_slice(&(h.owners.len() as u32).to_le_bytes());
                for &o in &h.owners {
                    out.extend_from_slice(&o.to_le_bytes());
                }
                out.extend_from_slice(&(h.peers.len() as u32).to_le_bytes());
                for p in &h.peers {
                    out.extend_from_slice(&(p.len() as u32).to_le_bytes());
                    out.extend_from_slice(p.as_bytes());
                }
                out.extend_from_slice(&h.job.encode());
            }
            ServiceOpen::Keys(k) => {
                out.push(1);
                out.extend_from_slice(&k.job_id.to_le_bytes());
                out.extend_from_slice(&k.owner_id.to_le_bytes());
                out.extend_from_slice(&k.requester_id.to_le_bytes());
            }
            ServiceOpen::Ack { worker_id } => {
                out.push(2);
                out.extend_from_slice(&worker_id.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ServiceOpen> {
        let mut c = Cursor::new(buf);
        let open = match c.u8("service hello role")? {
            0 => {
                let job_id = c.u64("service hello")?;
                let worker_id = c.u16("service hello")?;
                let epoch = c.u32("service hello")?;
                let decode_threads = c.u16("service hello")?;
                let nowners = c.u32("service hello")? as usize;
                anyhow::ensure!(
                    nowners <= MAX_SERVICE_COLS,
                    "unreasonable owner-table length {nowners}"
                );
                let mut owners = Vec::with_capacity(nowners);
                for _ in 0..nowners {
                    owners.push(c.u16("service hello owners")?);
                }
                let npeers = c.u32("service hello")? as usize;
                anyhow::ensure!(npeers <= MAX_SERVICE_COLS, "unreasonable peer count {npeers}");
                let mut peers = Vec::with_capacity(npeers);
                for _ in 0..npeers {
                    let len = c.u32("service hello peer")? as usize;
                    let raw = c.take(len, "service hello peer")?;
                    peers.push(
                        std::str::from_utf8(raw)
                            .map_err(|e| anyhow::anyhow!("peer address is not UTF-8: {e}"))?
                            .to_string(),
                    );
                }
                let job = Job::decode(&buf[c.at..])?;
                ServiceOpen::Dispatch(ServiceHello {
                    job_id,
                    worker_id,
                    epoch,
                    owners,
                    peers,
                    decode_threads,
                    job,
                })
            }
            1 => {
                let k = KeyHello {
                    job_id: c.u64("key hello")?,
                    owner_id: c.u16("key hello")?,
                    requester_id: c.u16("key hello")?,
                };
                c.done("key hello")?;
                ServiceOpen::Keys(k)
            }
            2 => {
                let worker_id = c.u16("service ack")?;
                c.done("service ack")?;
                ServiceOpen::Ack { worker_id }
            }
            other => anyhow::bail!("unknown service hello role {other}"),
        };
        Ok(open)
    }
}

/// Dispatcher → worker: metadata for one split. The split's raw bytes
/// follow as `FusedChunk`* + `FusedEnd` frames, so a mid-split fault
/// lands mid-stream exactly as on the old two-pass path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitAssign {
    /// Global split sequence number — the determinism backbone: owners
    /// assign vocabulary indices in `(seq, in-split appearance)` order.
    pub seq: u64,
    /// Ownership epoch (and table) the worker must route keys under.
    pub epoch: u32,
    /// Rows the dispatcher expects back (kept + skipped + quarantined);
    /// a mismatch marks the split failed and re-dispatches it.
    pub expected_rows: u64,
    /// Current per-column owner worker ids.
    pub owners: Vec<u16>,
}

impl SplitAssign {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + self.owners.len() * 2);
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.expected_rows.to_le_bytes());
        out.extend_from_slice(&(self.owners.len() as u32).to_le_bytes());
        for &o in &self.owners {
            out.extend_from_slice(&o.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<SplitAssign> {
        let mut c = Cursor::new(buf);
        let seq = c.u64("split assign")?;
        let epoch = c.u32("split assign")?;
        let expected_rows = c.u64("split assign")?;
        let nowners = c.u32("split assign")? as usize;
        anyhow::ensure!(nowners <= MAX_SERVICE_COLS, "unreasonable owner-table length {nowners}");
        let mut owners = Vec::with_capacity(nowners);
        for _ in 0..nowners {
            owners.push(c.u16("split assign owners")?);
        }
        c.done("split assign")?;
        Ok(SplitAssign { seq, epoch, expected_rows, owners })
    }
}

/// One split's unique raw keys for one column, appearance-ordered
/// (requester → owner), and the owner's index reply. The `(col, seq)`
/// pair makes both frames self-describing, so replies can be matched
/// without any per-session request state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KeyBatch {
    pub col: u16,
    pub seq: u64,
    pub keys: Vec<u32>,
}

impl KeyBatch {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 + self.keys.len() * 4);
        out.extend_from_slice(&self.col.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.keys.len() as u32).to_le_bytes());
        for &k in &self.keys {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<KeyBatch> {
        let mut c = Cursor::new(buf);
        let col = c.u16("key batch")?;
        let seq = c.u64("key batch")?;
        let keys = c.u32s("key batch")?;
        c.done("key batch")?;
        Ok(KeyBatch { col, seq, keys })
    }
}

/// Owner → requester: globally-assigned indices for one [`KeyBatch`],
/// in the same order as the batch's keys.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexBatch {
    pub col: u16,
    pub seq: u64,
    pub indices: Vec<u32>,
}

impl IndexBatch {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 + self.indices.len() * 4);
        out.extend_from_slice(&self.col.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.indices.len() as u32).to_le_bytes());
        for &i in &self.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<IndexBatch> {
        let mut c = Cursor::new(buf);
        let col = c.u16("index batch")?;
        let seq = c.u64("index batch")?;
        let indices = c.u32s("index batch")?;
        c.done("index batch")?;
        Ok(IndexBatch { col, seq, indices })
    }
}

/// Worker → dispatcher: one split's `(keys, indices)` vocabulary delta
/// for one column. The dispatcher folds deltas in `seq` order into its
/// mirror of every column vocabulary — the state that survives an
/// owner's departure — and verifies the owner-assigned indices match
/// the deterministic fold.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VocabDelta {
    pub col: u16,
    pub seq: u64,
    /// The split's unique mapped keys in appearance order.
    pub keys: Vec<u32>,
    /// The global indices the owner assigned, parallel to `keys`.
    pub indices: Vec<u32>,
}

impl VocabDelta {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(18 + self.keys.len() * 8);
        out.extend_from_slice(&self.col.to_le_bytes());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.keys.len() as u32).to_le_bytes());
        for &k in &self.keys {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out.extend_from_slice(&(self.indices.len() as u32).to_le_bytes());
        for &i in &self.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<VocabDelta> {
        let mut c = Cursor::new(buf);
        let col = c.u16("vocab delta")?;
        let seq = c.u64("vocab delta")?;
        let keys = c.u32s("vocab delta keys")?;
        let indices = c.u32s("vocab delta indices")?;
        c.done("vocab delta")?;
        anyhow::ensure!(
            keys.len() == indices.len(),
            "vocab delta keys/indices length mismatch ({} vs {})",
            keys.len(),
            indices.len()
        );
        Ok(VocabDelta { col, seq, keys, indices })
    }
}

/// Dispatcher → worker: seed a column sequencer after an ownership
/// transfer — the mirror's contiguously-folded keys plus the next
/// split seq the fold expects. Seeding is a liveness aid (batches below
/// the watermark are never re-submitted); a fresh sequencer refolding
/// from zero produces identical indices by determinism.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OwnerSeed {
    pub col: u16,
    pub next_seq: u64,
    /// The mirror vocabulary's keys in global appearance order.
    pub keys: Vec<u32>,
}

impl OwnerSeed {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 + self.keys.len() * 4);
        out.extend_from_slice(&self.col.to_le_bytes());
        out.extend_from_slice(&self.next_seq.to_le_bytes());
        out.extend_from_slice(&(self.keys.len() as u32).to_le_bytes());
        for &k in &self.keys {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<OwnerSeed> {
        let mut c = Cursor::new(buf);
        let col = c.u16("owner seed")?;
        let next_seq = c.u64("owner seed")?;
        let keys = c.u32s("owner seed")?;
        c.done("owner seed")?;
        Ok(OwnerSeed { col, next_seq, keys })
    }
}

/// Terminal status of one split (worker → dispatcher). The dispatcher
/// reuses the same frame with `seq == u64::MAX` (`SplitDone::END`) as
/// the clean end-of-job marker on a dispatch session.
#[derive(Debug, Clone, PartialEq)]
pub struct SplitDone {
    pub seq: u64,
    pub status: SplitStatus,
}

#[derive(Debug, Clone, PartialEq)]
pub enum SplitStatus {
    Ok(RunStats),
    Failed(String),
}

impl SplitDone {
    /// The `seq` value that marks a clean end of job.
    pub const END: u64 = u64::MAX;

    pub fn end_marker() -> SplitDone {
        SplitDone { seq: SplitDone::END, status: SplitStatus::Ok(RunStats::default()) }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(73);
        out.extend_from_slice(&self.seq.to_le_bytes());
        match &self.status {
            SplitStatus::Ok(stats) => {
                out.push(0);
                out.extend_from_slice(&stats.encode());
            }
            SplitStatus::Failed(reason) => {
                out.push(1);
                out.extend_from_slice(reason.as_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<SplitDone> {
        let mut c = Cursor::new(buf);
        let seq = c.u64("split done")?;
        let status = match c.u8("split done status")? {
            0 => SplitStatus::Ok(RunStats::decode(&buf[c.at..])?),
            1 => SplitStatus::Failed(
                std::str::from_utf8(&buf[c.at..])
                    .map_err(|e| anyhow::anyhow!("split failure reason is not UTF-8: {e}"))?
                    .to_string(),
            ),
            other => anyhow::bail!("unknown split status byte {other}"),
        };
        Ok(SplitDone { seq, status })
    }
}

/// Pack a service-path ResultChunk: the split's `seq:u64le` followed by
/// [`pack_rows`] bytes, so the dispatcher's per-worker reader threads
/// can attribute rows to splits on a multiplexed session.
pub fn pack_service_rows(seq: u64, rows: &[ProcessedRow], schema: Schema) -> Vec<u8> {
    let mut out = seq.to_le_bytes().to_vec();
    out.extend_from_slice(&pack_rows(rows, schema));
    out
}

/// Decode [`pack_service_rows`] output.
pub fn unpack_service_rows(buf: &[u8], schema: Schema) -> Result<(u64, Vec<ProcessedRow>)> {
    anyhow::ensure!(buf.len() >= 8, "service result chunk truncated: {} bytes", buf.len());
    let seq = u64::from_le_bytes([
        buf[0], buf[1], buf[2], buf[3], buf[4], buf[5], buf[6], buf[7],
    ]);
    Ok((seq, unpack_rows(&buf[8..], schema)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::Pass1Chunk, b"hello").unwrap();
        write_frame(&mut buf, Tag::Pass1End, b"").unwrap();
        let mut r = &buf[..];
        let (t1, p1) = read_frame(&mut r).unwrap();
        assert_eq!((t1, p1.as_slice()), (Tag::Pass1Chunk, &b"hello"[..]));
        let (t2, p2) = read_frame(&mut r).unwrap();
        assert_eq!((t2, p2.len()), (Tag::Pass1End, 0));
    }

    #[test]
    fn bad_tag_rejected() {
        // A well-formed frame (correct length + checksum) with an
        // unknown tag must be rejected as Malformed, not panic.
        let mut buf = vec![99u8];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&frame_sum(99, &[]).to_le_bytes());
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::Malformed { .. })), "{err:#}");
    }

    #[test]
    fn corrupt_payload_rejected_by_checksum() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::ResultChunk, b"payload-bytes").unwrap();
        for at in 0..buf.len() {
            let mut bad = buf.clone();
            bad[at] ^= 0x40;
            let got = read_frame(&mut &bad[..]);
            // Any single-bit flip in header or payload must surface as
            // an error (usually Malformed; a flipped length bit can
            // also truncate → PeerGone). Never a silent success.
            assert!(got.is_err(), "flip at {at} went undetected");
        }
        // the original still reads fine
        let (tag, payload) = read_frame(&mut &buf[..]).unwrap();
        assert_eq!((tag, payload.as_slice()), (Tag::ResultChunk, &b"payload-bytes"[..]));
    }

    #[test]
    fn io_errors_classified() {
        // EOF mid-frame → PeerGone
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::Pass1Chunk, b"0123456789").unwrap();
        let err = read_frame(&mut &buf[..buf.len() - 3]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::PeerGone { .. })), "{err:#}");
        // taxonomy: retryability is part of the contract
        assert!(NetError::Timeout { what: "t".into() }.retryable());
        assert!(NetError::PeerGone { what: "p".into() }.retryable());
        assert!(NetError::Overloaded.retryable());
        assert!(!NetError::Malformed { what: "m".into() }.retryable());
        assert!(
            !NetError::JobFailed { worker: "w".into(), reason: "r".into() }.retryable()
        );
    }

    #[test]
    fn shard_dump_roundtrip() {
        let cols = vec![vec![5u32, 1, 9], vec![], vec![42]];
        let packed = pack_shard_dump(123, &cols);
        assert_eq!(unpack_shard_dump(&packed).unwrap(), (123, cols));
        assert!(unpack_shard_dump(&packed[..7]).is_err());
        assert!(unpack_shard_dump(&packed[..packed.len() - 1]).is_err());
    }

    #[test]
    fn job_roundtrip() {
        let job = Job::dlrm(Schema::new(13, 26), Modulus::VOCAB_5K, WireFormat::Binary);
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_roundtrip_heterogeneous_spec() {
        let job = Job {
            schema: Schema::new(13, 26),
            spec: PipelineSpec::parse(
                "sparse[*]: modulus:5000|genvocab|applyvocab; \
                 sparse[0..4]: modulus:100000|genvocab|applyvocab; \
                 dense[*]: neg2zero|log; dense[3]: clip:0:100|bucketize:1:10:100",
            )
            .unwrap(),
            format: WireFormat::Utf8,
            errors: ErrorConfig::default(),
        };
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_decode_rejects_garbage() {
        assert!(Job::decode(&[0u8; 4]).is_err(), "short frame");
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[8] = 9;
        assert!(Job::decode(&bad).is_err(), "bad format byte");
        let mut junk = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        junk.truncate(9);
        junk.extend_from_slice(b"frobnicate");
        assert!(Job::decode(&junk).is_err(), "invalid spec string");
    }

    #[test]
    fn rows_roundtrip() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, 3] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let packed = pack_rows(&rows, schema);
        assert_eq!(unpack_rows(&packed, schema).unwrap(), rows);
    }

    #[test]
    fn pack_columns_matches_pack_rows() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, u32::MAX] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let mut cols = ProcessedColumns::with_schema(schema);
        for r in &rows {
            cols.push_row(r);
        }
        assert_eq!(pack_columns(&cols, schema), pack_rows(&rows, schema));
    }

    #[test]
    fn vocab_roundtrip_and_hostile_lengths() {
        let cols = vec![vec![5, 1, 9], vec![], vec![42]];
        let packed = pack_vocabs(&cols);
        assert_eq!(unpack_vocabs(&packed).unwrap(), cols);
        // truncation anywhere is an error, never a panic
        for cut in 0..packed.len() {
            assert!(unpack_vocabs(&packed[..cut]).is_err(), "cut at {cut}");
        }
        // a column length far beyond the buffer must fail fast without
        // a giant reservation
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&1u32.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(unpack_vocabs(&hostile).is_err());
        // trailing bytes rejected
        let mut trailing = pack_vocabs(&cols);
        trailing.push(0);
        assert!(unpack_vocabs(&trailing).is_err());
    }

    #[test]
    fn stats_roundtrip() {
        let s = RunStats {
            rows: 123,
            vocab_entries: 456,
            rows_skipped: 7,
            rows_quarantined: 8,
            illegal_bytes: 9,
            decode_ns: 1_000_001,
            stateless_ns: 2_000_002,
            vocab_ns: 3_000_003,
        };
        assert_eq!(RunStats::decode(&s.encode()).unwrap(), s);
        assert!(RunStats::decode(&s.encode()[..16]).is_err(), "old 16-byte frame rejected");
        assert!(RunStats::decode(&s.encode()[..40]).is_err(), "pre-PR10 40-byte frame rejected");
    }

    #[test]
    fn service_open_roundtrip() {
        let hello = ServiceOpen::Dispatch(ServiceHello {
            job_id: 0xDEAD_BEEF_0042,
            worker_id: 3,
            epoch: 7,
            owners: vec![0, 1, 2, 0, 1],
            peers: vec!["127.0.0.1:4000".into(), "127.0.0.1:4001".into()],
            decode_threads: 2,
            job: Job::dlrm(Schema::new(2, 5), Modulus::VOCAB_5K, WireFormat::Binary),
        });
        assert_eq!(ServiceOpen::decode(&hello.encode()).unwrap(), hello);
        let keys =
            ServiceOpen::Keys(KeyHello { job_id: 99, owner_id: 1, requester_id: 2 });
        assert_eq!(ServiceOpen::decode(&keys.encode()).unwrap(), keys);
        let ack = ServiceOpen::Ack { worker_id: 5 };
        assert_eq!(ServiceOpen::decode(&ack.encode()).unwrap(), ack);
        // hostile inputs: truncations and a bad role are typed errors
        let enc = hello.encode();
        for cut in 0..enc.len().min(64) {
            assert!(ServiceOpen::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
        assert!(ServiceOpen::decode(&[9u8]).is_err(), "bad role byte");
        // an owner count far beyond the buffer fails fast
        let mut hostile = vec![0u8];
        hostile.extend_from_slice(&1u64.to_le_bytes());
        hostile.extend_from_slice(&0u16.to_le_bytes());
        hostile.extend_from_slice(&0u32.to_le_bytes());
        hostile.extend_from_slice(&0u16.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(ServiceOpen::decode(&hostile).is_err(), "hostile owner count");
    }

    #[test]
    fn split_assign_roundtrip() {
        let a = SplitAssign { seq: 17, epoch: 3, expected_rows: 4096, owners: vec![1, 0, 1] };
        assert_eq!(SplitAssign::decode(&a.encode()).unwrap(), a);
        let enc = a.encode();
        for cut in 0..enc.len() {
            assert!(SplitAssign::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
        let mut trailing = enc.clone();
        trailing.push(0);
        assert!(SplitAssign::decode(&trailing).is_err(), "trailing bytes");
    }

    #[test]
    fn key_and_index_batch_roundtrip() {
        let kb = KeyBatch { col: 4, seq: 9, keys: vec![10, 20, 30] };
        assert_eq!(KeyBatch::decode(&kb.encode()).unwrap(), kb);
        let ib = IndexBatch { col: 4, seq: 9, indices: vec![0, 1, 2] };
        assert_eq!(IndexBatch::decode(&ib.encode()).unwrap(), ib);
        // hostile length: claims far more keys than the frame holds
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&4u16.to_le_bytes());
        hostile.extend_from_slice(&9u64.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(KeyBatch::decode(&hostile).is_err());
        assert!(IndexBatch::decode(&hostile).is_err());
        for cut in 0..kb.encode().len() {
            assert!(KeyBatch::decode(&kb.encode()[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn vocab_delta_and_owner_seed_roundtrip() {
        let d = VocabDelta { col: 2, seq: 5, keys: vec![7, 8], indices: vec![0, 1] };
        assert_eq!(VocabDelta::decode(&d.encode()).unwrap(), d);
        // mismatched key/index lengths are rejected
        let bad = VocabDelta { col: 2, seq: 5, keys: vec![7, 8], indices: vec![0] };
        assert!(VocabDelta::decode(&bad.encode()).is_err());
        let s = OwnerSeed { col: 2, next_seq: 6, keys: vec![7, 8, 9] };
        assert_eq!(OwnerSeed::decode(&s.encode()).unwrap(), s);
        for cut in 0..d.encode().len() {
            assert!(VocabDelta::decode(&d.encode()[..cut]).is_err(), "cut at {cut}");
        }
        for cut in 0..s.encode().len() {
            assert!(OwnerSeed::decode(&s.encode()[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn split_done_roundtrip() {
        let ok = SplitDone {
            seq: 12,
            status: SplitStatus::Ok(RunStats { rows: 9, ..RunStats::default() }),
        };
        assert_eq!(SplitDone::decode(&ok.encode()).unwrap(), ok);
        let failed =
            SplitDone { seq: 13, status: SplitStatus::Failed("budget exceeded".into()) };
        assert_eq!(SplitDone::decode(&failed.encode()).unwrap(), failed);
        let end = SplitDone::end_marker();
        assert_eq!(SplitDone::decode(&end.encode()).unwrap().seq, SplitDone::END);
        assert!(SplitDone::decode(&ok.encode()[..8]).is_err(), "missing status byte");
        assert!(SplitDone::decode(&[0u8; 9]).is_err(), "ok status without stats");
    }

    #[test]
    fn service_rows_roundtrip() {
        let schema = Schema::new(1, 2);
        let rows = vec![ProcessedRow { label: 1, dense: vec![0.5], sparse: vec![3, 4] }];
        let packed = pack_service_rows(42, &rows, schema);
        assert_eq!(unpack_service_rows(&packed, schema).unwrap(), (42, rows));
        assert!(unpack_service_rows(&packed[..7], schema).is_err(), "truncated seq");
        assert!(unpack_service_rows(&packed[..packed.len() - 1], schema).is_err());
    }

    #[test]
    fn job_roundtrip_error_config() {
        for (policy, budget) in [
            (ErrorPolicy::Fail, ErrorBudget::Unlimited),
            (ErrorPolicy::Skip, ErrorBudget::Count(42)),
            (ErrorPolicy::Quarantine, ErrorBudget::Rate(0.125)),
        ] {
            let job = Job {
                errors: ErrorConfig { policy, budget, detail_cap: 17 },
                ..Job::dlrm(Schema::new(13, 26), Modulus::VOCAB_5K, WireFormat::Utf8)
            };
            assert_eq!(Job::decode(&job.encode()).unwrap(), job);
        }
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[9] = 77;
        assert!(Job::decode(&bad).is_err(), "bad policy byte");
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[10] = 77;
        assert!(Job::decode(&bad).is_err(), "bad budget tag");
    }

    #[test]
    fn frame_cap_enforced() {
        let mut buf = vec![Tag::Job as u8];
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]);
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(matches!(NetError::of(&err), Some(NetError::Malformed { .. })), "{err:#}");
    }
}
