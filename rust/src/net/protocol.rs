//! Wire protocol: length-prefixed tagged frames over TCP.
//!
//! ```text
//! frame := tag:u8 len:u64le payload[len]
//! ```
//!
//! Leader → worker, two-pass protocol: `Job`, `Pass1Chunk`*, `Pass1End`,
//! `Pass2Chunk`*, `Pass2End`. Fused single-pass protocol: `Job`,
//! `FusedChunk`*, `FusedEnd` — the dataset crosses the wire **once**,
//! appearance indices are assigned on the fly and results stream back
//! while the input is still arriving. Worker → leader: `ResultChunk`*
//! (packed processed rows), `ResultEnd` (stats). The strategy is not in
//! the job header — the first data frame picks the protocol, so old
//! leaders keep working and the cluster leader-merge path simply keeps
//! sending pass frames.

use crate::data::row::{ProcessedColumns, ProcessedRow};
use crate::data::Schema;
use crate::ops::{Modulus, PipelineSpec};
use crate::Result;
use std::io::{Read, Write};

use super::stream::WireFormat;

/// Frame tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    Job = 1,
    Pass1Chunk = 2,
    Pass1End = 3,
    Pass2Chunk = 4,
    Pass2End = 5,
    ResultChunk = 6,
    ResultEnd = 7,
    /// Leader → worker (cluster mode, after Pass1End): request the
    /// worker's sub-vocabularies for the global merge.
    VocabSync = 8,
    /// Worker → leader: sub-vocabulary keys in appearance order.
    VocabDump = 9,
    /// Leader → worker: the merged global vocabularies to apply in pass 2.
    VocabLoad = 10,
    /// Leader → worker (fused single-pass protocol): a raw chunk to
    /// observe *and* process in one scan.
    FusedChunk = 11,
    /// Leader → worker: end of the fused stream.
    FusedEnd = 12,
    /// Client → worker, first frame of the serving protocol: a frozen
    /// artifact plus miss policy and admission settings
    /// ([`crate::net::serve::ServeJob`]).
    ServeJob = 13,
    /// Client → worker: one small-batch request
    /// (`req_id:u64` + raw rows in the session's wire format).
    ServeRequest = 14,
    /// Worker → client: the response to one request
    /// ([`crate::net::serve::ServeResponse`]).
    ServeResponse = 15,
    /// Client → worker: end of the serving session.
    ServeEnd = 16,
    /// Worker → client, final frame of a serving session: aggregate
    /// latency/miss statistics ([`crate::net::serve::ServeReport`]).
    ServeReport = 17,
    /// Worker → peer: a fatal protocol/session error, carried as a
    /// UTF-8 message just before the worker closes the connection — so
    /// a malformed stream diagnoses itself instead of surfacing as a
    /// bare hangup on the other side.
    ErrorReply = 18,
}

impl Tag {
    pub fn from_u8(v: u8) -> Result<Tag> {
        Ok(match v {
            1 => Tag::Job,
            2 => Tag::Pass1Chunk,
            3 => Tag::Pass1End,
            4 => Tag::Pass2Chunk,
            5 => Tag::Pass2End,
            6 => Tag::ResultChunk,
            7 => Tag::ResultEnd,
            8 => Tag::VocabSync,
            9 => Tag::VocabDump,
            10 => Tag::VocabLoad,
            11 => Tag::FusedChunk,
            12 => Tag::FusedEnd,
            13 => Tag::ServeJob,
            14 => Tag::ServeRequest,
            15 => Tag::ServeResponse,
            16 => Tag::ServeEnd,
            17 => Tag::ServeReport,
            18 => Tag::ErrorReply,
            other => anyhow::bail!("unknown frame tag {other}"),
        })
    }
}

/// Encode per-column vocabulary keys: `ncols:u32 (len:u32 keys:u32*)*`.
pub fn pack_vocabs(cols: &[Vec<u32>]) -> Vec<u8> {
    let total: usize = cols.iter().map(|c| c.len()).sum();
    let mut out = Vec::with_capacity(4 + cols.len() * 4 + total * 4);
    out.extend_from_slice(&(cols.len() as u32).to_le_bytes());
    for col in cols {
        out.extend_from_slice(&(col.len() as u32).to_le_bytes());
        for &k in col {
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out
}

/// Decode [`pack_vocabs`] output.
pub fn unpack_vocabs(buf: &[u8]) -> Result<Vec<Vec<u32>>> {
    let rd_u32 = |at: usize| -> Result<u32> {
        let s = buf
            .get(at..at + 4)
            .ok_or_else(|| anyhow::anyhow!("vocab frame truncated at {at}"))?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    };
    let ncols = rd_u32(0)? as usize;
    anyhow::ensure!(ncols <= 4096, "unreasonable column count {ncols}");
    let mut cols = Vec::with_capacity(ncols);
    let mut at = 4;
    for _ in 0..ncols {
        let len = rd_u32(at)? as usize;
        at += 4;
        // Bound the reservation by the bytes actually present: a
        // malicious length field must produce a truncation error, not a
        // multi-gigabyte allocation.
        anyhow::ensure!(
            buf.len().saturating_sub(at) / 4 >= len,
            "vocab frame truncated: column claims {len} keys"
        );
        let mut col = Vec::with_capacity(len);
        for _ in 0..len {
            col.push(rd_u32(at)?);
            at += 4;
        }
        cols.push(col);
    }
    anyhow::ensure!(at == buf.len(), "trailing bytes in vocab frame");
    Ok(cols)
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, tag: Tag, payload: &[u8]) -> Result<()> {
    w.write_all(&[tag as u8])?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame. Payload size is capped to keep a corrupt peer from
/// forcing a huge allocation.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(Tag, Vec<u8>)> {
    const MAX_FRAME: u64 = 1 << 30;
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len);
    anyhow::ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds cap");
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok((Tag::from_u8(tag[0])?, payload))
}

/// Job header: schema, wire format and the full per-column operator
/// spec. The spec crosses the wire in its canonical [`PipelineSpec`]
/// display form and is re-parsed (and therefore re-validated) on the
/// worker — `parse(display(spec)) == spec` is pinned by the spec
/// round-trip property test.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub schema: Schema,
    pub spec: PipelineSpec,
    pub format: WireFormat,
}

impl Job {
    /// The classic fixed-pipeline job: the paper's DLRM preset at one
    /// uniform vocabulary size (what the old modulus-only header could
    /// express).
    pub fn dlrm(schema: Schema, modulus: Modulus, format: WireFormat) -> Job {
        Job { schema, spec: PipelineSpec::dlrm(modulus.range), format }
    }

    /// Frame layout: `num_dense:u32 num_sparse:u32 format:u8 spec:utf8`
    /// (the spec takes the rest of the frame — frames are already
    /// length-prefixed).
    pub fn encode(&self) -> Vec<u8> {
        let spec = self.spec.to_string();
        let mut out = Vec::with_capacity(9 + spec.len());
        out.extend_from_slice(&(self.schema.num_dense as u32).to_le_bytes());
        out.extend_from_slice(&(self.schema.num_sparse as u32).to_le_bytes());
        out.push(match self.format {
            WireFormat::Utf8 => 0,
            WireFormat::Binary => 1,
        });
        out.extend_from_slice(spec.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Job> {
        anyhow::ensure!(buf.len() >= 9, "job frame must be >= 9 bytes, got {}", buf.len());
        let rd = |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
        let format = match buf[8] {
            0 => WireFormat::Utf8,
            1 => WireFormat::Binary,
            v => anyhow::bail!("bad wire format {v}"),
        };
        let spec = std::str::from_utf8(&buf[9..])
            .map_err(|e| anyhow::anyhow!("job spec is not UTF-8: {e}"))?;
        Ok(Job {
            schema: Schema::new(rd(0) as usize, rd(4) as usize),
            spec: PipelineSpec::parse(spec)?,
            format,
        })
    }
}

/// Pack processed rows for a ResultChunk: per row
/// `label:i32 dense...:f32 sparse...:u32`, all little-endian.
pub fn pack_rows(rows: &[ProcessedRow], schema: Schema) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * schema.binary_row_bytes());
    for r in rows {
        out.extend_from_slice(&r.label.to_le_bytes());
        for &d in &r.dense {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for &s in &r.sparse {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
    out
}

/// Unpack a ResultChunk.
pub fn unpack_rows(buf: &[u8], schema: Schema) -> Result<Vec<ProcessedRow>> {
    let rb = schema.binary_row_bytes();
    anyhow::ensure!(buf.len() % rb == 0, "result chunk misaligned");
    let mut rows = Vec::with_capacity(buf.len() / rb);
    for chunk in buf.chunks_exact(rb) {
        let w = |i: usize| [chunk[4 * i], chunk[4 * i + 1], chunk[4 * i + 2], chunk[4 * i + 3]];
        let label = i32::from_le_bytes(w(0));
        let dense = (0..schema.num_dense)
            .map(|c| f32::from_le_bytes(w(1 + c)))
            .collect();
        let sparse = (0..schema.num_sparse)
            .map(|c| u32::from_le_bytes(w(1 + schema.num_dense + c)))
            .collect();
        rows.push(ProcessedRow { label, dense, sparse });
    }
    Ok(rows)
}

/// Pack a processed column block straight into the [`pack_rows`] wire
/// layout — same bytes, no intermediate [`ProcessedRow`] materialization
/// (the serving path packs every response, so the per-row allocation of
/// a `row()` round trip would be pure overhead).
pub fn pack_columns(cols: &ProcessedColumns, schema: Schema) -> Vec<u8> {
    let rows = cols.num_rows();
    let mut out = Vec::with_capacity(rows * schema.binary_row_bytes());
    for r in 0..rows {
        out.extend_from_slice(&cols.labels[r].to_le_bytes());
        for col in &cols.dense {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
        for col in &cols.sparse {
            out.extend_from_slice(&col[r].to_le_bytes());
        }
    }
    out
}

/// Stats returned in ResultEnd.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    pub rows: u64,
    pub vocab_entries: u64,
}

impl RunStats {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&self.rows.to_le_bytes());
        out.extend_from_slice(&self.vocab_entries.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunStats> {
        anyhow::ensure!(buf.len() == 16, "stats frame must be 16 bytes");
        let rd = |i: usize| {
            u64::from_le_bytes([
                buf[i], buf[i + 1], buf[i + 2], buf[i + 3],
                buf[i + 4], buf[i + 5], buf[i + 6], buf[i + 7],
            ])
        };
        Ok(RunStats { rows: rd(0), vocab_entries: rd(8) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Tag::Pass1Chunk, b"hello").unwrap();
        write_frame(&mut buf, Tag::Pass1End, b"").unwrap();
        let mut r = &buf[..];
        let (t1, p1) = read_frame(&mut r).unwrap();
        assert_eq!((t1, p1.as_slice()), (Tag::Pass1Chunk, &b"hello"[..]));
        let (t2, p2) = read_frame(&mut r).unwrap();
        assert_eq!((t2, p2.len()), (Tag::Pass1End, 0));
    }

    #[test]
    fn bad_tag_rejected() {
        let buf = [99u8, 0, 0, 0, 0, 0, 0, 0, 0];
        assert!(read_frame(&mut &buf[..]).is_err());
    }

    #[test]
    fn job_roundtrip() {
        let job = Job::dlrm(Schema::new(13, 26), Modulus::VOCAB_5K, WireFormat::Binary);
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_roundtrip_heterogeneous_spec() {
        let job = Job {
            schema: Schema::new(13, 26),
            spec: PipelineSpec::parse(
                "sparse[*]: modulus:5000|genvocab|applyvocab; \
                 sparse[0..4]: modulus:100000|genvocab|applyvocab; \
                 dense[*]: neg2zero|log; dense[3]: clip:0:100|bucketize:1:10:100",
            )
            .unwrap(),
            format: WireFormat::Utf8,
        };
        assert_eq!(Job::decode(&job.encode()).unwrap(), job);
    }

    #[test]
    fn job_decode_rejects_garbage() {
        assert!(Job::decode(&[0u8; 4]).is_err(), "short frame");
        let mut bad = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        bad[8] = 9;
        assert!(Job::decode(&bad).is_err(), "bad format byte");
        let mut junk = Job::dlrm(Schema::CRITEO, Modulus::VOCAB_5K, WireFormat::Utf8).encode();
        junk.truncate(9);
        junk.extend_from_slice(b"frobnicate");
        assert!(Job::decode(&junk).is_err(), "invalid spec string");
    }

    #[test]
    fn rows_roundtrip() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, 3] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let packed = pack_rows(&rows, schema);
        assert_eq!(unpack_rows(&packed, schema).unwrap(), rows);
    }

    #[test]
    fn pack_columns_matches_pack_rows() {
        let schema = Schema::new(2, 3);
        let rows = vec![
            ProcessedRow { label: 1, dense: vec![0.5, -2.0], sparse: vec![1, 2, u32::MAX] },
            ProcessedRow { label: 0, dense: vec![1.5, 9.0], sparse: vec![4, 5, 6] },
        ];
        let mut cols = ProcessedColumns::with_schema(schema);
        for r in &rows {
            cols.push_row(r);
        }
        assert_eq!(pack_columns(&cols, schema), pack_rows(&rows, schema));
    }

    #[test]
    fn vocab_roundtrip_and_hostile_lengths() {
        let cols = vec![vec![5, 1, 9], vec![], vec![42]];
        let packed = pack_vocabs(&cols);
        assert_eq!(unpack_vocabs(&packed).unwrap(), cols);
        // truncation anywhere is an error, never a panic
        for cut in 0..packed.len() {
            assert!(unpack_vocabs(&packed[..cut]).is_err(), "cut at {cut}");
        }
        // a column length far beyond the buffer must fail fast without
        // a giant reservation
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&1u32.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(unpack_vocabs(&hostile).is_err());
        // trailing bytes rejected
        let mut trailing = pack_vocabs(&cols);
        trailing.push(0);
        assert!(unpack_vocabs(&trailing).is_err());
    }

    #[test]
    fn stats_roundtrip() {
        let s = RunStats { rows: 123, vocab_entries: 456 };
        assert_eq!(RunStats::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn frame_cap_enforced() {
        let mut buf = vec![Tag::Job as u8];
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame(&mut &buf[..]).is_err());
    }
}
