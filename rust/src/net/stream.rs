//! Streaming preprocessor — the worker-side core, independent of the
//! transport so it can be tested without sockets. Speaks both execution
//! strategies: the classic two-pass protocol (pass 1 GenVocab, pass 2
//! ApplyVocab — required by the cluster leader-merge, whose vocabulary
//! barrier sits between the passes) and the fused single-pass protocol
//! (observe + emit per chunk, the dataset arrives once).

use crate::accel::InputFormat;
use crate::data::row::{ProcessedColumns, ProcessedRow};
use crate::data::{RowBlock, Schema};
use crate::ops::{log1p, HashVocab, Modulus, Vocab, VOCAB_MISS};
use crate::pipeline::{ChunkDecoder, DecodeOptions, ExecStrategy};
use crate::Result;

/// Raw wire format of the incoming stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    Utf8,
    Binary,
}

impl From<WireFormat> for InputFormat {
    fn from(w: WireFormat) -> InputFormat {
        match w {
            WireFormat::Utf8 => InputFormat::Utf8,
            WireFormat::Binary => InputFormat::Binary,
        }
    }
}

/// Phase of the streaming protocol. The first data chunk commits the
/// strategy: `Pass1` (two-pass) or `Fused` (single pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Nothing received yet — either protocol may start.
    Start,
    Pass1,
    BetweenPasses,
    Pass2,
    Fused,
    Done,
}

/// The streaming preprocessor. Two-pass: GenVocab during pass 1,
/// ApplyVocab + dense finishing during pass 2. Fused: both in one scan
/// per chunk ([`Self::fused_chunk`]), emitting rows immediately. Shares
/// the engine's [`ChunkDecoder`] and decodes every chunk into one
/// reusable column-major [`RowBlock`] scratch — memory high-water is
/// the vocabularies plus one chunk, never the dataset, and no per-row
/// allocation happens on any pass.
#[derive(Debug)]
pub struct StreamingPreprocessor {
    schema: Schema,
    modulus: Modulus,
    format: WireFormat,
    decode: DecodeOptions,
    vocabs: Vec<HashVocab>,
    decoder: ChunkDecoder,
    scratch: RowBlock,
    phase: Phase,
    rows_pass1: usize,
    rows_pass2: usize,
}

impl StreamingPreprocessor {
    /// Sequential decode (decode threads = 1) — deterministic across
    /// deployments and right for the small frames tests feed.
    pub fn new(schema: Schema, modulus: Modulus, format: WireFormat) -> Self {
        Self::with_decode_options(schema, modulus, format, DecodeOptions::default())
    }

    /// Worker deployments pass the engine's decode options here so wire
    /// chunks fan out across decode threads exactly like local chunks
    /// ([`crate::decode::shard`]); output is bit-identical either way.
    pub fn with_decode_options(
        schema: Schema,
        modulus: Modulus,
        format: WireFormat,
        decode: DecodeOptions,
    ) -> Self {
        StreamingPreprocessor {
            schema,
            modulus,
            format,
            decode,
            vocabs: (0..schema.num_sparse).map(|_| HashVocab::new()).collect(),
            decoder: ChunkDecoder::with_options(format.into(), schema, decode),
            scratch: RowBlock::new(schema),
            phase: Phase::Start,
            rows_pass1: 0,
            rows_pass2: 0,
        }
    }

    /// Pass-1 chunk: observe sparse values into the vocabularies.
    pub fn pass1_chunk(&mut self, chunk: &[u8]) -> Result<()> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Pass1),
            "pass1_chunk in phase {:?}",
            self.phase
        );
        self.phase = Phase::Pass1;
        self.scratch.clear();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        self.observe_scratch();
        Ok(())
    }

    /// End of pass 1: flush the decoder, reset it for pass 2.
    pub fn pass1_end(&mut self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Pass1),
            "pass1_end in phase {:?}",
            self.phase
        );
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema, self.decode),
        );
        self.scratch.clear();
        decoder.finish_into(&mut self.scratch)?;
        self.observe_scratch();
        self.phase = Phase::BetweenPasses;
        Ok(())
    }

    /// GenVocab over the scratch block: one tight loop per sparse column.
    fn observe_scratch(&mut self) {
        let m = self.modulus;
        for (c, vocab) in self.vocabs.iter_mut().enumerate() {
            for &s in self.scratch.sparse_col(c) {
                vocab.observe(m.apply(s));
            }
        }
        self.rows_pass1 += self.scratch.num_rows();
    }

    /// Pass-2 chunk: returns the preprocessed rows it completes.
    pub fn pass2_chunk(&mut self, chunk: &[u8]) -> Result<Vec<ProcessedRow>> {
        if self.phase == Phase::BetweenPasses {
            self.phase = Phase::Pass2;
        }
        anyhow::ensure!(self.phase == Phase::Pass2, "pass2_chunk in phase {:?}", self.phase);
        self.scratch.clear();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        let out = self.apply_scratch();
        self.rows_pass2 += out.len();
        Ok(out)
    }

    /// End of pass 2: flush, return trailing rows.
    pub fn pass2_end(&mut self) -> Result<Vec<ProcessedRow>> {
        if self.phase == Phase::BetweenPasses {
            self.phase = Phase::Pass2; // empty pass 2 is legal
        }
        anyhow::ensure!(self.phase == Phase::Pass2, "pass2_end in phase {:?}", self.phase);
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema, self.decode),
        );
        self.scratch.clear();
        decoder.finish_into(&mut self.scratch)?;
        let out = self.apply_scratch();
        self.rows_pass2 += out.len();
        self.phase = Phase::Done;
        Ok(out)
    }

    /// Fused chunk: observe sparse values *and* emit processed rows in
    /// one scan — the single-pass protocol. Bit-identical to the
    /// two-pass result because appearance indices are fixed at first
    /// appearance.
    pub fn fused_chunk(&mut self, chunk: &[u8]) -> Result<Vec<ProcessedRow>> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Fused),
            "fused_chunk in phase {:?}",
            self.phase
        );
        self.phase = Phase::Fused;
        self.scratch.clear();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        let out = self.fuse_scratch();
        self.rows_pass1 += out.len();
        self.rows_pass2 += out.len();
        Ok(out)
    }

    /// End of the fused stream: flush the decoder, return trailing rows.
    pub fn fused_end(&mut self) -> Result<Vec<ProcessedRow>> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Fused),
            "fused_end in phase {:?}",
            self.phase
        );
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema, self.decode),
        );
        self.scratch.clear();
        decoder.finish_into(&mut self.scratch)?;
        let out = self.fuse_scratch();
        self.rows_pass1 += out.len();
        self.rows_pass2 += out.len();
        self.phase = Phase::Done;
        Ok(out)
    }

    /// Fused GenVocab+ApplyVocab + dense finishing over the scratch
    /// block. Row-major iteration visits each column's values in row
    /// order, so [`Vocab::observe_apply`] assigns exactly the indices
    /// the column-major two-pass scan does.
    fn fuse_scratch(&mut self) -> Vec<ProcessedRow> {
        let m = self.modulus;
        let schema = self.schema;
        let block = &self.scratch;
        let vocabs = &mut self.vocabs;
        let n = block.num_rows();
        let dcols: Vec<&[i32]> = (0..schema.num_dense).map(|c| block.dense_col(c)).collect();
        let scols: Vec<&[u32]> = (0..schema.num_sparse).map(|c| block.sparse_col(c)).collect();
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let dense = dcols.iter().map(|col| log1p(col[r])).collect();
            let mut sparse = Vec::with_capacity(schema.num_sparse);
            for (col, vocab) in scols.iter().zip(vocabs.iter_mut()) {
                sparse.push(vocab.observe_apply(m.apply(col[r])));
            }
            out.push(ProcessedRow { label: block.labels()[r], dense, sparse });
        }
        out
    }

    /// ApplyVocab + dense finishing over the scratch block, re-assembled
    /// into the wire's row-major frames. Column slices are hoisted once
    /// per chunk so the per-row transpose does no repeated slicing.
    fn apply_scratch(&self) -> Vec<ProcessedRow> {
        let block = &self.scratch;
        let n = block.num_rows();
        let dcols: Vec<&[i32]> = (0..self.schema.num_dense).map(|c| block.dense_col(c)).collect();
        let scols: Vec<&[u32]> =
            (0..self.schema.num_sparse).map(|c| block.sparse_col(c)).collect();
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let dense = dcols.iter().map(|col| log1p(col[r])).collect();
            let sparse = scols
                .iter()
                .zip(&self.vocabs)
                // a miss is impossible after pass 1 / a vocab import;
                // the sentinel keeps it loud instead of aliasing index 0
                .map(|(col, vocab)| vocab.apply(self.modulus.apply(col[r])).unwrap_or(VOCAB_MISS))
                .collect();
            out.push(ProcessedRow { label: block.labels()[r], dense, sparse });
        }
        out
    }

    pub fn vocab_entries(&self) -> usize {
        self.vocabs.iter().map(|v| v.len()).sum()
    }

    /// Export the per-column vocabularies as keys in appearance order —
    /// the payload a cluster worker ships to the leader for the global
    /// merge (multi-accelerator deployment, paper §3.4.2/§4.4.6).
    pub fn export_vocabs(&self) -> Vec<Vec<u32>> {
        self.vocabs
            .iter()
            .map(|v| v.iter_ordered().map(|(k, _)| k).collect())
            .collect()
    }

    /// Replace the vocabularies with merged global ones (keys in global
    /// appearance order). Called between the passes on cluster workers.
    pub fn import_vocabs(&mut self, columns: Vec<Vec<u32>>) -> Result<()> {
        anyhow::ensure!(
            columns.len() == self.schema.num_sparse,
            "vocab import has {} columns, schema wants {}",
            columns.len(),
            self.schema.num_sparse
        );
        anyhow::ensure!(
            self.phase == Phase::BetweenPasses,
            "vocab import only between passes (phase {:?})",
            self.phase
        );
        self.vocabs = columns
            .into_iter()
            .map(|keys| {
                let mut v = HashVocab::new();
                for k in keys {
                    v.observe(k);
                }
                v
            })
            .collect();
        Ok(())
    }

    pub fn rows_seen(&self) -> (usize, usize) {
        (self.rows_pass1, self.rows_pass2)
    }
}

/// Convenience: preprocess an in-memory buffer with a given chunk size
/// under either strategy, collecting columns (used by tests and the
/// leader's loopback fallback).
pub fn preprocess_buffered(
    schema: Schema,
    modulus: Modulus,
    format: WireFormat,
    raw: &[u8],
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<ProcessedColumns> {
    let mut sp = StreamingPreprocessor::new(schema, modulus, format);
    let mut cols = ProcessedColumns::with_schema(schema);
    match strategy {
        ExecStrategy::TwoPass => {
            for chunk in raw.chunks(chunk_size.max(1)) {
                sp.pass1_chunk(chunk)?;
            }
            sp.pass1_end()?;
            for chunk in raw.chunks(chunk_size.max(1)) {
                for row in sp.pass2_chunk(chunk)? {
                    cols.push_row(&row);
                }
            }
            for row in sp.pass2_end()? {
                cols.push_row(&row);
            }
        }
        ExecStrategy::Fused => {
            for chunk in raw.chunks(chunk_size.max(1)) {
                for row in sp.fused_chunk(chunk)? {
                    cols.push_row(&row);
                }
            }
            for row in sp.fused_end()? {
                cols.push_row(&row);
            }
        }
    }
    Ok(cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};

    #[test]
    fn streaming_matches_batch_for_all_chunk_sizes() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);

        let reference = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        )
        .processed;

        for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
            for chunk in [1usize, 3, 17, 64, 1024, raw.len()] {
                let got = preprocess_buffered(
                    ds.schema(), m, WireFormat::Utf8, &raw, chunk, strategy,
                ).unwrap();
                assert_eq!(got, reference, "chunk size {chunk} ({strategy:?})");
            }
        }
    }

    #[test]
    fn binary_stream_matches_utf8_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let m = Modulus::new(499);
        for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
            let u = preprocess_buffered(
                ds.schema(), m, WireFormat::Utf8, &utf8::encode_dataset(&ds), 53, strategy,
            ).unwrap();
            let b = preprocess_buffered(
                ds.schema(), m, WireFormat::Binary, &binary::encode_dataset(&ds), 53, strategy,
            ).unwrap();
            assert_eq!(u, b, "{strategy:?}");
        }
    }

    /// The worker's strategies must agree bit for bit — the wire-level
    /// face of the fused == two-pass identity.
    #[test]
    fn fused_stream_matches_two_pass_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(260));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let two = preprocess_buffered(
            ds.schema(), m, WireFormat::Utf8, &raw, 97, ExecStrategy::TwoPass,
        ).unwrap();
        let fused = preprocess_buffered(
            ds.schema(), m, WireFormat::Utf8, &raw, 97, ExecStrategy::Fused,
        ).unwrap();
        assert_eq!(fused, two);
    }

    #[test]
    fn strategies_cannot_mix_mid_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(5));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(ds.schema(), Modulus::new(97), WireFormat::Utf8);
        sp.fused_chunk(&raw).unwrap();
        assert!(sp.pass1_chunk(&raw).is_err(), "two-pass frame after fused must fail");
        assert!(sp.pass2_chunk(&raw).is_err());
        sp.fused_end().unwrap();
        assert!(sp.fused_chunk(&raw).is_err(), "fused after done must fail");
    }

    #[test]
    fn phase_order_enforced() {
        let ds = SynthDataset::generate(SynthConfig::small(5));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(ds.schema(), Modulus::new(97), WireFormat::Utf8);
        // pass2 before pass1_end is an error
        assert!(sp.pass2_chunk(&raw).is_err());
        sp.pass1_chunk(&raw).unwrap();
        sp.pass1_end().unwrap();
        assert!(sp.pass1_chunk(&raw).is_err(), "pass1 after end must fail");
        sp.pass2_chunk(&raw).unwrap();
        sp.pass2_end().unwrap();
        assert!(sp.pass2_chunk(&raw).is_err(), "pass2 after done must fail");
    }

    #[test]
    fn truncated_binary_is_rejected() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let mut raw = binary::encode_dataset(&ds);
        raw.pop(); // corrupt
        let mut sp =
            StreamingPreprocessor::new(ds.schema(), Modulus::new(97), WireFormat::Binary);
        sp.pass1_chunk(&raw).unwrap();
        assert!(sp.pass1_end().is_err());
    }

    #[test]
    fn vocab_counts_reported() {
        let ds = SynthDataset::generate(SynthConfig::small(100));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(ds.schema(), Modulus::new(997), WireFormat::Utf8);
        sp.pass1_chunk(&raw).unwrap();
        sp.pass1_end().unwrap();
        assert!(sp.vocab_entries() > 0);
        assert_eq!(sp.rows_seen().0, 100);
    }
}
