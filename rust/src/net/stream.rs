//! Streaming preprocessor — the worker-side core, independent of the
//! transport so it can be tested without sockets. Runs the job's
//! compiled per-column programs through the engine's shared functional
//! core ([`ChunkState`]), so a wire job supports everything a local
//! plan does (per-column vocabulary sizes, partial dense chains,
//! clip/bucketize) with bit-identical output. Speaks both execution
//! strategies: the classic two-pass protocol (pass 1 GenVocab, pass 2
//! ApplyVocab — required by the cluster leader-merge, whose vocabulary
//! barrier sits between the passes) and the fused single-pass protocol
//! (observe + emit per chunk, the dataset arrives once).

use crate::accel::InputFormat;
use crate::data::row::{ProcessedColumns, ProcessedRow};
use crate::data::{RowBlock, Schema};
use crate::decode::{DataError, DecodeTally, ErrorPolicy};
use crate::ops::PipelineSpec;
use crate::pipeline::{ChunkDecoder, ChunkState, DecodeOptions, ExecStrategy};
use crate::Result;

/// Raw wire format of the incoming stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    Utf8,
    Binary,
}

impl From<WireFormat> for InputFormat {
    fn from(w: WireFormat) -> InputFormat {
        match w {
            WireFormat::Utf8 => InputFormat::Utf8,
            WireFormat::Binary => InputFormat::Binary,
        }
    }
}

/// Phase of the streaming protocol. The first data chunk commits the
/// strategy: `Pass1` (two-pass) or `Fused` (single pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Nothing received yet — either protocol may start.
    Start,
    Pass1,
    BetweenPasses,
    Pass2,
    Fused,
    Done,
}

/// The streaming preprocessor. Two-pass: GenVocab during pass 1,
/// ApplyVocab + dense finishing during pass 2. Fused: both in one scan
/// per chunk ([`Self::fused_chunk`]), emitting rows immediately. Shares
/// the engine's [`ChunkDecoder`] and per-column [`ChunkState`], and
/// decodes every chunk into one reusable column-major [`RowBlock`]
/// scratch — memory high-water is the vocabularies plus one chunk,
/// never the dataset.
#[derive(Debug)]
pub struct StreamingPreprocessor {
    state: ChunkState,
    format: WireFormat,
    /// The caller's options verbatim; `decode.errors` is the job-level
    /// policy counters are attributed under.
    decode: DecodeOptions,
    /// What decoders actually run with: quarantine downgraded to skip
    /// (raw quarantined bytes never cross the wire — the side file is a
    /// leader-local artifact; the worker contains identically and
    /// reports the count).
    decoder_opts: DecodeOptions,
    decoder: ChunkDecoder,
    scratch: RowBlock,
    phase: Phase,
    rows_pass1: usize,
    rows_pass2: usize,
    /// Total rows decoded in pass 1 — kept *and* contained. This is the
    /// count the cluster leader verifies against the shard's true row
    /// count, so it must be invariant under the containment policy.
    observed_pass1: u64,
    /// Decode tally of the emit pass (pass 2, or the fused pass) —
    /// captured at stream end, the source of the worker's containment
    /// counters. Two-pass decodes the bytes twice but reports once.
    emit_tally: DecodeTally,
    /// Per-stage wall time (see [`Self::stage_ns`]).
    decode_ns: u64,
    stateless_ns: u64,
    vocab_ns: u64,
}

impl StreamingPreprocessor {
    /// Sequential decode (decode threads = 1) — deterministic across
    /// deployments and right for the small frames tests feed. Compiles
    /// the spec against the schema (the worker-side planning step — a
    /// selector/schema mismatch fails here, before any data frame).
    pub fn new(spec: &PipelineSpec, schema: Schema, format: WireFormat) -> Result<Self> {
        Self::with_decode_options(spec, schema, format, DecodeOptions::default())
    }

    /// Worker deployments pass the engine's decode options here so wire
    /// chunks fan out across decode threads exactly like local chunks
    /// ([`crate::decode::shard`]); output is bit-identical either way.
    pub fn with_decode_options(
        spec: &PipelineSpec,
        schema: Schema,
        format: WireFormat,
        decode: DecodeOptions,
    ) -> Result<Self> {
        let decoder_opts = DecodeOptions { errors: decode.errors.for_observe_pass(), ..decode };
        Ok(StreamingPreprocessor {
            state: ChunkState::with_programs(spec.compile(schema)?),
            format,
            decode,
            decoder_opts,
            decoder: ChunkDecoder::with_options(format.into(), schema, decoder_opts),
            scratch: RowBlock::new(schema),
            phase: Phase::Start,
            rows_pass1: 0,
            rows_pass2: 0,
            observed_pass1: 0,
            emit_tally: DecodeTally::default(),
            decode_ns: 0,
            stateless_ns: 0,
            vocab_ns: 0,
        })
    }

    fn schema(&self) -> Schema {
        self.state.schema()
    }

    /// Abort the stream with a typed [`DataError`] once contained rows
    /// exceed the job's error budget; checked after every fed chunk.
    fn check_budget(&self) -> Result<()> {
        let log = self.decoder.errors();
        let rows = self.decoder.rows_seen();
        if self.decode.errors.budget.exceeded(log.total, rows) {
            return Err(anyhow::Error::new(DataError::BudgetExceeded {
                errors: log.total,
                rows,
                budget: self.decode.errors.budget,
                first: log.first().copied(),
            }));
        }
        Ok(())
    }

    /// Final budget check against a finished pass's tally (the trailing
    /// row can add one last defect the per-chunk checks never saw).
    fn check_tally_budget(&self, tally: &DecodeTally) -> Result<()> {
        if self.decode.errors.budget.exceeded(tally.errors.total, tally.rows_seen) {
            return Err(anyhow::Error::new(DataError::BudgetExceeded {
                errors: tally.errors.total,
                rows: tally.rows_seen,
                budget: self.decode.errors.budget,
                first: tally.errors.first().copied(),
            }));
        }
        Ok(())
    }

    /// Pass-1 chunk: observe sparse values into the vocabularies.
    pub fn pass1_chunk(&mut self, chunk: &[u8]) -> Result<()> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Pass1),
            "protocol violation: pass1_chunk in phase {:?}",
            self.phase
        );
        self.phase = Phase::Pass1;
        self.scratch.clear();
        let t0 = std::time::Instant::now();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        let t1 = std::time::Instant::now();
        self.decode_ns += (t1 - t0).as_nanos() as u64;
        self.check_budget()?;
        self.state.observe(&self.scratch);
        self.vocab_ns += t1.elapsed().as_nanos() as u64;
        self.rows_pass1 += self.scratch.num_rows();
        Ok(())
    }

    /// End of pass 1: flush the decoder, reset it for pass 2.
    pub fn pass1_end(&mut self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Pass1),
            "protocol violation: pass1_end in phase {:?}",
            self.phase
        );
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema(), self.decoder_opts),
        );
        self.scratch.clear();
        // The emit pass reports the containment counters; pass 1 keeps
        // only the observed-row total the leader's integrity check needs.
        let t0 = std::time::Instant::now();
        let tally = decoder.finish_into(&mut self.scratch)?;
        let t1 = std::time::Instant::now();
        self.decode_ns += (t1 - t0).as_nanos() as u64;
        self.check_tally_budget(&tally)?;
        self.observed_pass1 = tally.rows_seen;
        self.state.observe(&self.scratch);
        self.vocab_ns += t1.elapsed().as_nanos() as u64;
        self.rows_pass1 += self.scratch.num_rows();
        self.phase = Phase::BetweenPasses;
        Ok(())
    }

    /// Pass-2 chunk: returns the preprocessed rows it completes.
    pub fn pass2_chunk(&mut self, chunk: &[u8]) -> Result<Vec<ProcessedRow>> {
        if self.phase == Phase::BetweenPasses {
            self.phase = Phase::Pass2;
        }
        anyhow::ensure!(
            self.phase == Phase::Pass2,
            "protocol violation: pass2_chunk in phase {:?}",
            self.phase
        );
        self.scratch.clear();
        let t0 = std::time::Instant::now();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        let t1 = std::time::Instant::now();
        self.decode_ns += (t1 - t0).as_nanos() as u64;
        self.check_budget()?;
        let out = rows_of(&self.state.process(&self.scratch));
        self.stateless_ns += t1.elapsed().as_nanos() as u64;
        self.rows_pass2 += out.len();
        Ok(out)
    }

    /// End of pass 2: flush, return trailing rows.
    pub fn pass2_end(&mut self) -> Result<Vec<ProcessedRow>> {
        if self.phase == Phase::BetweenPasses {
            self.phase = Phase::Pass2; // empty pass 2 is legal
        }
        anyhow::ensure!(
            self.phase == Phase::Pass2,
            "protocol violation: pass2_end in phase {:?}",
            self.phase
        );
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema(), self.decoder_opts),
        );
        self.scratch.clear();
        let t0 = std::time::Instant::now();
        self.emit_tally = decoder.finish_into(&mut self.scratch)?;
        let t1 = std::time::Instant::now();
        self.decode_ns += (t1 - t0).as_nanos() as u64;
        self.check_tally_budget(&self.emit_tally)?;
        let out = rows_of(&self.state.process(&self.scratch));
        self.stateless_ns += t1.elapsed().as_nanos() as u64;
        self.rows_pass2 += out.len();
        self.phase = Phase::Done;
        Ok(out)
    }

    /// Fused chunk: observe sparse values *and* emit processed rows in
    /// one scan — the single-pass protocol ([`ChunkState::process_fused`],
    /// the same fused core the local executors run). Bit-identical to
    /// the two-pass result because appearance indices are fixed at
    /// first appearance.
    pub fn fused_chunk(&mut self, chunk: &[u8]) -> Result<Vec<ProcessedRow>> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Fused),
            "protocol violation: fused_chunk in phase {:?}",
            self.phase
        );
        self.phase = Phase::Fused;
        self.scratch.clear();
        let t0 = std::time::Instant::now();
        self.decoder.feed_into(chunk, &mut self.scratch)?;
        let t1 = std::time::Instant::now();
        self.decode_ns += (t1 - t0).as_nanos() as u64;
        self.check_budget()?;
        let out = rows_of(&self.fused_block());
        self.rows_pass1 += out.len();
        self.rows_pass2 += out.len();
        Ok(out)
    }

    /// [`ChunkState::process_fused`] over the scratch block, with the
    /// stateless and vocabulary stages timed separately (same two calls
    /// `process_fused` makes, so output is bit-identical).
    fn fused_block(&mut self) -> ProcessedColumns {
        let t0 = std::time::Instant::now();
        let mut out =
            self.state.process_stateless_range(&self.scratch, 0..self.scratch.num_rows());
        let t1 = std::time::Instant::now();
        self.stateless_ns += (t1 - t0).as_nanos() as u64;
        self.state.fuse_sparse(&self.scratch, &mut out);
        self.vocab_ns += t1.elapsed().as_nanos() as u64;
        out
    }

    /// End of the fused stream: flush the decoder, return trailing rows.
    pub fn fused_end(&mut self) -> Result<Vec<ProcessedRow>> {
        anyhow::ensure!(
            matches!(self.phase, Phase::Start | Phase::Fused),
            "protocol violation: fused_end in phase {:?}",
            self.phase
        );
        let decoder = std::mem::replace(
            &mut self.decoder,
            ChunkDecoder::with_options(self.format.into(), self.schema(), self.decoder_opts),
        );
        self.scratch.clear();
        let t0 = std::time::Instant::now();
        self.emit_tally = decoder.finish_into(&mut self.scratch)?;
        self.decode_ns += t0.elapsed().as_nanos() as u64;
        self.check_tally_budget(&self.emit_tally)?;
        let out = rows_of(&self.fused_block());
        self.rows_pass1 += out.len();
        self.rows_pass2 += out.len();
        self.phase = Phase::Done;
        Ok(out)
    }

    pub fn vocab_entries(&self) -> usize {
        self.state.vocab_entries()
    }

    /// Per-stage wall nanoseconds: `(decode, stateless, vocab)`. Fused
    /// streams attribute the stateless per-column programs and the
    /// sequential vocabulary fold separately; two-pass streams charge
    /// pass 1's observe to vocab and pass 2's emit to stateless.
    pub fn stage_ns(&self) -> (u64, u64, u64) {
        (self.decode_ns, self.stateless_ns, self.vocab_ns)
    }

    /// Add externally-measured vocabulary-stage time (the service
    /// path's remote index waits and sparse rewrites).
    pub fn add_vocab_ns(&mut self, ns: u64) {
        self.vocab_ns += ns;
    }

    /// Export the per-column vocabularies as keys in appearance order —
    /// the payload a cluster worker ships to the leader for the global
    /// merge (multi-accelerator deployment, paper §3.4.2/§4.4.6).
    /// Columns whose program builds no vocabulary export empty lists.
    pub fn export_vocabs(&self) -> Vec<Vec<u32>> {
        self.state
            .vocabs
            .iter()
            .map(|v| v.iter_ordered().map(|(k, _)| k).collect())
            .collect()
    }

    /// Replace the vocabularies with merged global ones (keys in global
    /// appearance order). Called between the passes on cluster workers.
    pub fn import_vocabs(&mut self, columns: Vec<Vec<u32>>) -> Result<()> {
        anyhow::ensure!(
            columns.len() == self.schema().num_sparse,
            "vocab import has {} columns, schema wants {}",
            columns.len(),
            self.schema().num_sparse
        );
        anyhow::ensure!(
            self.phase == Phase::BetweenPasses,
            "protocol violation: vocab import only between passes (phase {:?})",
            self.phase
        );
        use crate::ops::Vocab as _;
        self.state.vocabs = columns
            .into_iter()
            .map(|keys| {
                let mut v = crate::ops::HashVocab::new();
                for k in keys {
                    v.observe(k);
                }
                v
            })
            .collect();
        Ok(())
    }

    pub fn rows_seen(&self) -> (usize, usize) {
        (self.rows_pass1, self.rows_pass2)
    }

    /// Rows decoded during pass 1, including contained ones — the
    /// shard-dump row count the cluster leader checks against the
    /// shard's true size (valid after `pass1_end`).
    pub fn observed_rows(&self) -> u64 {
        self.observed_pass1
    }

    /// The emit pass's decode tally (valid after `pass2_end`/`fused_end`).
    pub fn emit_tally(&self) -> &DecodeTally {
        &self.emit_tally
    }

    /// Containment counters for the wire stats, attributed under the
    /// job's policy: `(rows_skipped, rows_quarantined, illegal_bytes)`.
    pub fn containment(&self) -> (u64, u64, u64) {
        let t = &self.emit_tally;
        match self.decode.errors.policy {
            ErrorPolicy::Skip => (t.errors.total, 0, t.illegal.total),
            ErrorPolicy::Quarantine => (0, t.errors.total, t.illegal.total),
            _ => (0, 0, t.illegal.total),
        }
    }
}

/// Re-assemble a column block into the wire's row-major frames.
fn rows_of(cols: &ProcessedColumns) -> Vec<ProcessedRow> {
    (0..cols.num_rows()).map(|r| cols.row(r)).collect()
}

/// Convenience: preprocess an in-memory buffer with a given chunk size
/// under either strategy, collecting columns (used by tests and the
/// leader's loopback fallback).
pub fn preprocess_buffered(
    spec: &PipelineSpec,
    schema: Schema,
    format: WireFormat,
    raw: &[u8],
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<ProcessedColumns> {
    let mut sp = StreamingPreprocessor::new(spec, schema, format)?;
    let mut cols = ProcessedColumns::with_schema(schema);
    match strategy {
        ExecStrategy::TwoPass => {
            for chunk in raw.chunks(chunk_size.max(1)) {
                sp.pass1_chunk(chunk)?;
            }
            sp.pass1_end()?;
            for chunk in raw.chunks(chunk_size.max(1)) {
                for row in sp.pass2_chunk(chunk)? {
                    cols.push_row(&row);
                }
            }
            for row in sp.pass2_end()? {
                cols.push_row(&row);
            }
        }
        ExecStrategy::Fused => {
            for chunk in raw.chunks(chunk_size.max(1)) {
                for row in sp.fused_chunk(chunk)? {
                    cols.push_row(&row);
                }
            }
            for row in sp.fused_end()? {
                cols.push_row(&row);
            }
        }
    }
    Ok(cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    fn dlrm(m: Modulus) -> PipelineSpec {
        PipelineSpec::dlrm(m.range)
    }

    #[test]
    fn streaming_matches_batch_for_all_chunk_sizes() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);

        let reference = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        )
        .processed;

        for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
            for chunk in [1usize, 3, 17, 64, 1024, raw.len()] {
                let got = preprocess_buffered(
                    &dlrm(m), ds.schema(), WireFormat::Utf8, &raw, chunk, strategy,
                ).unwrap();
                assert_eq!(got, reference, "chunk size {chunk} ({strategy:?})");
            }
        }
    }

    #[test]
    fn binary_stream_matches_utf8_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(150));
        let m = Modulus::new(499);
        for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
            let u = preprocess_buffered(
                &dlrm(m), ds.schema(), WireFormat::Utf8, &utf8::encode_dataset(&ds), 53, strategy,
            ).unwrap();
            let b = preprocess_buffered(
                &dlrm(m), ds.schema(), WireFormat::Binary, &binary::encode_dataset(&ds), 53,
                strategy,
            ).unwrap();
            assert_eq!(u, b, "{strategy:?}");
        }
    }

    /// The worker's strategies must agree bit for bit — the wire-level
    /// face of the fused == two-pass identity.
    #[test]
    fn fused_stream_matches_two_pass_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(260));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let two = preprocess_buffered(
            &dlrm(m), ds.schema(), WireFormat::Utf8, &raw, 97, ExecStrategy::TwoPass,
        ).unwrap();
        let fused = preprocess_buffered(
            &dlrm(m), ds.schema(), WireFormat::Utf8, &raw, 97, ExecStrategy::Fused,
        ).unwrap();
        assert_eq!(fused, two);
    }

    /// A heterogeneous per-column job through the wire core equals the
    /// spec's reference interpreter, under both strategies.
    #[test]
    fn per_column_programs_stream_bit_identically() {
        let ds = SynthDataset::generate(SynthConfig::small(240));
        let spec = PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             sparse[5]: modulus:53; \
             dense[*]: neg2zero|log; \
             dense[0]: clip:0:100|bucketize:1:10:100; \
             dense[1]: neg2zero",
        )
        .unwrap();
        let reference = spec.execute(&ds.rows, ds.schema()).unwrap();
        for (format, raw) in [
            (WireFormat::Utf8, utf8::encode_dataset(&ds)),
            (WireFormat::Binary, binary::encode_dataset(&ds)),
        ] {
            for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
                let got = preprocess_buffered(
                    &spec, ds.schema(), format, &raw, 131, strategy,
                ).unwrap();
                assert_eq!(got, reference, "{format:?} {strategy:?}");
            }
        }
    }

    #[test]
    fn spec_schema_mismatch_fails_at_construction() {
        let spec = PipelineSpec::parse("sparse[40]: modulus:7|genvocab|applyvocab").unwrap();
        assert!(
            StreamingPreprocessor::new(&spec, crate::data::Schema::CRITEO, WireFormat::Utf8)
                .is_err(),
            "selector out of schema must fail before any data frame"
        );
    }

    #[test]
    fn strategies_cannot_mix_mid_stream() {
        let ds = SynthDataset::generate(SynthConfig::small(5));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(&dlrm(Modulus::new(97)), ds.schema(), WireFormat::Utf8)
                .unwrap();
        sp.fused_chunk(&raw).unwrap();
        assert!(sp.pass1_chunk(&raw).is_err(), "two-pass frame after fused must fail");
        assert!(sp.pass2_chunk(&raw).is_err());
        sp.fused_end().unwrap();
        assert!(sp.fused_chunk(&raw).is_err(), "fused after done must fail");
    }

    #[test]
    fn phase_order_enforced() {
        let ds = SynthDataset::generate(SynthConfig::small(5));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(&dlrm(Modulus::new(97)), ds.schema(), WireFormat::Utf8)
                .unwrap();
        // pass2 before pass1_end is an error
        assert!(sp.pass2_chunk(&raw).is_err());
        sp.pass1_chunk(&raw).unwrap();
        sp.pass1_end().unwrap();
        assert!(sp.pass1_chunk(&raw).is_err(), "pass1 after end must fail");
        sp.pass2_chunk(&raw).unwrap();
        sp.pass2_end().unwrap();
        assert!(sp.pass2_chunk(&raw).is_err(), "pass2 after done must fail");
    }

    #[test]
    fn truncated_binary_is_rejected() {
        let ds = SynthDataset::generate(SynthConfig::small(3));
        let mut raw = binary::encode_dataset(&ds);
        raw.pop(); // corrupt
        let mut sp =
            StreamingPreprocessor::new(&dlrm(Modulus::new(97)), ds.schema(), WireFormat::Binary)
                .unwrap();
        sp.pass1_chunk(&raw).unwrap();
        assert!(sp.pass1_end().is_err());
    }

    #[test]
    fn vocab_counts_reported() {
        let ds = SynthDataset::generate(SynthConfig::small(100));
        let raw = utf8::encode_dataset(&ds);
        let mut sp =
            StreamingPreprocessor::new(&dlrm(Modulus::new(997)), ds.schema(), WireFormat::Utf8)
                .unwrap();
        sp.pass1_chunk(&raw).unwrap();
        sp.pass1_end().unwrap();
        assert!(sp.vocab_entries() > 0);
        assert_eq!(sp.rows_seen().0, 100);
    }
}
