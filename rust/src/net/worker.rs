//! The accelerator node: accept a job over TCP, run the streaming
//! preprocessor, stream results back. Speaks all four protocols — the
//! first frame decides: a [`Tag::Job`] header opens a batch session
//! where the next data frame picks the dataflow (`FusedChunk` runs the
//! single-pass fused dataflow, `Pass1Chunk` the two-pass protocol the
//! cluster leader-merge requires); a [`Tag::ServeJob`] header opens an
//! online serving session against a frozen artifact
//! ([`crate::net::serve`]); a [`Tag::ServiceHello`] header opens a
//! preprocessing-service session ([`crate::service`]) — either the
//! dispatcher's split stream or a peer worker's key-forwarding lane.
//!
//! Accept loops are one-thread-per-connection: a service worker must
//! answer peers' key batches *while* its own dispatch session streams
//! a split, so sessions cannot be served serially.
//!
//! Error posture: any session error — malformed frame, bad job header,
//! decode failure — is reported to the peer as a [`Tag::ErrorReply`]
//! frame carrying the message, then the connection closes cleanly. A
//! hostile or buggy client costs the worker one connection, never the
//! process. Sockets carry the [`WorkerOptions`] I/O deadline, so a
//! leader that wedges mid-job costs the worker one timed-out
//! connection, never a thread parked forever; serving sessions switch
//! to the (default unbounded) idle deadline once the `ServeJob` header
//! arrives, because a quiet serving client is normal, not a fault.
//!
//! Lifecycle: [`serve_forever`] is the run-until-killed posture;
//! [`serve_until`] adds a [`ShutdownHandle`] — a poison-pill
//! `shutdown()` that lets an operator (or a test) stop the accept loop
//! while the in-flight session drains to completion first.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::Result;

use super::protocol::{self, NetError, RunStats, Tag};
use super::serve;
use super::stream::StreamingPreprocessor;

/// Worker-side socket posture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerOptions {
    /// Read/write deadline for batch sessions and the header frame. A
    /// peer that goes quiet longer than this costs one connection.
    pub io_timeout: Option<Duration>,
    /// Deadline once a session upgrades to serving. `None` (default):
    /// a serving client may idle between requests indefinitely.
    pub serve_idle_timeout: Option<Duration>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions { io_timeout: Some(Duration::from_secs(30)), serve_idle_timeout: None }
    }
}

/// Serve a single connection on `listener` and return after the job
/// completes. The caller loops for a long-lived service.
pub fn serve_one(listener: &TcpListener) -> Result<RunStats> {
    serve_one_opts(listener, &WorkerOptions::default())
}

/// [`serve_one`] with explicit socket deadlines.
pub fn serve_one_opts(listener: &TcpListener, opts: &WorkerOptions) -> Result<RunStats> {
    let (stream, _addr) = listener.accept()?;
    handle(stream, opts)
}

/// Serve `n` jobs then return (used by tests and the example binary).
pub fn serve_n(listener: &TcpListener, n: usize) -> Result<()> {
    for _ in 0..n {
        serve_one(listener)?;
    }
    Ok(())
}

/// Accept connections forever, one session thread per connection. A
/// failed session is logged and the worker keeps accepting — the
/// long-lived posture for a serving deployment.
pub fn serve_forever(listener: &TcpListener) -> ! {
    loop {
        match listener.accept() {
            Ok((stream, _addr)) => {
                std::thread::spawn(move || {
                    match handle(stream, &WorkerOptions::default()) {
                        Ok(stats) => eprintln!("session done: {} rows", stats.rows),
                        Err(e) => eprintln!("session failed: {e:#}"),
                    }
                });
            }
            Err(e) => eprintln!("accept failed: {e}"),
        }
    }
}

/// Graceful-stop control for a [`serve_until`] loop. Clone-cheap;
/// `shutdown()` may be called from any thread (or a signal handler
/// shim) and returns once the accept loop has been woken.
#[derive(Debug, Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
}

impl ShutdownHandle {
    /// A handle wired to `listener`'s address.
    pub fn new(listener: &TcpListener) -> Result<ShutdownHandle> {
        Ok(ShutdownHandle { flag: Arc::new(AtomicBool::new(false)), addr: listener.local_addr()? })
    }

    /// Request shutdown: raise the flag, then poke the listener with a
    /// poison-pill connection so a blocked `accept` wakes up and
    /// observes it. The in-flight session (if any) drains first —
    /// `serve_until` only rechecks the flag between sessions.
    pub fn shutdown(&self) {
        self.flag.store(true, Ordering::Release);
        // Best effort: if the loop already exited the connect fails,
        // which is exactly as good.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
    }

    pub fn is_shut_down(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// Accept and serve until `handle.shutdown()` is called, one session
/// thread per connection (a service worker answers peers' key batches
/// while its dispatch session streams). Sessions in flight when
/// shutdown is requested run to completion (drain) before the loop
/// returns the number of completed sessions. Failed sessions are
/// logged and counted, never fatal — same posture as [`serve_forever`].
pub fn serve_until(
    listener: &TcpListener,
    handle_: &ShutdownHandle,
    opts: &WorkerOptions,
) -> Result<u64> {
    let sessions = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut inflight = Vec::new();
    loop {
        if handle_.is_shut_down() {
            break;
        }
        let (stream, _addr) = listener.accept()?;
        if handle_.is_shut_down() {
            // The poison-pill connection (or a client racing it) —
            // drop it and exit; in-flight sessions drain below.
            break;
        }
        let opts = *opts;
        let counter = sessions.clone();
        inflight.push(std::thread::spawn(move || {
            match handle(stream, &opts) {
                Ok(stats) => eprintln!("session done: {} rows", stats.rows),
                Err(e) => eprintln!("session failed: {e:#}"),
            }
            counter.fetch_add(1, Ordering::AcqRel);
        }));
    }
    for t in inflight {
        let _ = t.join();
    }
    Ok(sessions.load(Ordering::Acquire))
}

fn handle(stream: TcpStream, opts: &WorkerOptions) -> Result<RunStats> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(opts.io_timeout)?;
    stream.set_write_timeout(opts.io_timeout)?;
    let mut reader = std::io::BufReader::with_capacity(1 << 20, stream.try_clone()?);
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream.try_clone()?);
    handle_connection(&mut reader, &mut writer, opts, Some(&stream))
}

/// One full worker session over any reader/writer pair — public so the
/// chaos harness can interpose [`crate::net::fault::FaultPlan`] wrappers
/// around a real socket and still run the production session code.
/// Every session error is reported to the peer as a best-effort
/// [`Tag::ErrorReply`] frame before the connection closes.
pub fn handle_connection<R, W>(
    reader: &mut R,
    writer: &mut W,
    opts: &WorkerOptions,
    sock: Option<&TcpStream>,
) -> Result<RunStats>
where
    R: Read + Send,
    W: Write,
{
    match session(reader, writer, opts, sock) {
        Ok(stats) => Ok(stats),
        Err(e) => {
            // Best effort: tell the peer why before hanging up. The
            // connection may already be gone — that must not mask the
            // original error.
            let _ = protocol::write_frame(writer, Tag::ErrorReply, e.to_string().as_bytes());
            let _ = writer.flush();
            Err(e)
        }
    }
}

/// Dispatch on the header frame, then run the chosen protocol to
/// completion. Every error propagates to [`handle_connection`], which
/// turns it into an [`Tag::ErrorReply`] frame.
fn session<R, W>(
    reader: &mut R,
    writer: &mut W,
    opts: &WorkerOptions,
    sock: Option<&TcpStream>,
) -> Result<RunStats>
where
    R: Read + Send,
    W: Write,
{
    // First frame must be a job header. Decoding it re-parses (and
    // re-validates) the per-column spec; compiling it against the job's
    // schema is the worker-side planning step — both fail here, before
    // any data frame is accepted.
    let (tag, payload) = protocol::read_frame(reader)?;
    match tag {
        Tag::Job => batch_session(reader, writer, protocol::Job::decode(&payload)?),
        Tag::ServeJob => {
            // Serving clients legitimately idle between requests —
            // relax the batch deadline to the serving one.
            if let Some(s) = sock {
                s.set_read_timeout(opts.serve_idle_timeout)?;
                s.set_write_timeout(opts.serve_idle_timeout)?;
            }
            let job = serve::ServeJob::decode(&payload)?;
            let report = serve::run_session(reader, writer, &job)?;
            Ok(RunStats {
                rows: report.rows,
                vocab_entries: job.artifact.total_entries() as u64,
                ..RunStats::default()
            })
        }
        Tag::ServiceHello => {
            // Service sessions legitimately idle — between splits, or
            // while a peer folds a key batch. Liveness is the
            // dispatcher's job (split deadlines, job clock), so reads
            // go unbounded once the session identifies itself.
            if let Some(s) = sock {
                s.set_read_timeout(None)?;
            }
            match protocol::ServiceOpen::decode(&payload)? {
                protocol::ServiceOpen::Dispatch(hello) => {
                    crate::service::session::dispatch_session(reader, writer, hello, opts)
                }
                protocol::ServiceOpen::Keys(hello) => {
                    crate::service::session::key_session(reader, writer, hello, opts)
                }
                protocol::ServiceOpen::Ack { .. } => anyhow::bail!(NetError::Malformed {
                    what: "an ack cannot open a service session".into(),
                }),
            }
        }
        other => anyhow::bail!(NetError::Malformed {
            what: format!("expected Job or ServeJob or ServiceHello frame, got {other:?}"),
        }),
    }
}

fn batch_session<R, W>(reader: &mut R, writer: &mut W, job: protocol::Job) -> Result<RunStats>
where
    R: Read,
    W: Write,
{
    // Worker posture: decode wire chunks with every local core (the
    // same row-sharded path the engine uses; output is bit-identical
    // to the sequential decode) under the job's containment policy.
    let decode = crate::pipeline::DecodeOptions {
        threads: crate::decode::shard::default_threads(),
        swar: true,
        errors: job.errors,
    };
    let mut sp =
        StreamingPreprocessor::with_decode_options(&job.spec, job.schema, job.format, decode)?;

    loop {
        let (tag, payload) = protocol::read_frame(reader)?;
        match tag {
            Tag::FusedChunk => {
                // Single-pass protocol: observe + apply in one scan,
                // stream the rows straight back.
                let rows = sp.fused_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::FusedEnd => {
                let rows = sp.fused_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
                let (rows_skipped, rows_quarantined, illegal_bytes) = sp.containment();
                let (decode_ns, stateless_ns, vocab_ns) = sp.stage_ns();
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                    rows_skipped,
                    rows_quarantined,
                    illegal_bytes,
                    decode_ns,
                    stateless_ns,
                    vocab_ns,
                };
                protocol::write_frame(writer, Tag::ResultEnd, &stats.encode())?;
                writer.flush()?;
                return Ok(stats);
            }
            Tag::Pass1Chunk => sp.pass1_chunk(&payload)?,
            Tag::Pass1End => sp.pass1_end()?,
            Tag::VocabSync => {
                // Cluster mode: ship sub-vocabularies for the global
                // merge (the one synchronization point of the sharded
                // deployment — paper §2.4's merge, moved to the leader),
                // prefixed with the rows this worker observed so the
                // leader can verify no pass-1 frame was lost.
                // Observed = kept + contained, so the count stays exact
                // under every containment policy.
                let dump = protocol::pack_shard_dump(sp.observed_rows(), &sp.export_vocabs());
                protocol::write_frame(writer, Tag::VocabDump, &dump)?;
                writer.flush()?;
            }
            Tag::VocabLoad => {
                sp.import_vocabs(protocol::unpack_vocabs(&payload)?)?;
            }
            Tag::Pass2Chunk => {
                // Stream results back immediately — the pipelined overlap
                // of Fig. 7d.
                let rows = sp.pass2_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::Pass2End => {
                let rows = sp.pass2_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
                let (rows_skipped, rows_quarantined, illegal_bytes) = sp.containment();
                let (decode_ns, stateless_ns, vocab_ns) = sp.stage_ns();
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                    rows_skipped,
                    rows_quarantined,
                    illegal_bytes,
                    decode_ns,
                    stateless_ns,
                    vocab_ns,
                };
                protocol::write_frame(writer, Tag::ResultEnd, &stats.encode())?;
                writer.flush()?;
                return Ok(stats);
            }
            other => anyhow::bail!(NetError::Malformed {
                what: format!("unexpected frame {other:?} from leader"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shutdown_stops_an_idle_accept_loop() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = ShutdownHandle::new(&listener).unwrap();
        let h2 = handle.clone();
        let t = std::thread::spawn(move || {
            serve_until(&listener, &h2, &WorkerOptions::default()).unwrap()
        });
        // Give the loop a moment to park in accept(), then poison it.
        std::thread::sleep(Duration::from_millis(50));
        handle.shutdown();
        let sessions = t.join().unwrap();
        assert_eq!(sessions, 0);
        assert!(handle.is_shut_down());
    }

    #[test]
    fn in_flight_session_drains_before_shutdown() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = ShutdownHandle::new(&listener).unwrap();
        let h2 = handle.clone();
        let t = std::thread::spawn(move || {
            serve_until(&listener, &h2, &WorkerOptions::default()).unwrap()
        });

        // A real (malformed) session: the worker answers with an
        // ErrorReply; only then is shutdown requested — the completed
        // session must be counted, and the loop must exit cleanly.
        let stream = TcpStream::connect(addr).unwrap();
        protocol::write_frame(&mut &stream, Tag::Pass1Chunk, b"no job header").unwrap();
        let (tag, payload) = protocol::read_frame(&mut &stream).unwrap();
        assert_eq!(tag, Tag::ErrorReply);
        assert!(
            String::from_utf8_lossy(&payload).contains("expected Job or ServeJob"),
            "worker explains the refusal"
        );
        drop(stream);
        handle.shutdown();
        let sessions = t.join().unwrap();
        assert_eq!(sessions, 1, "the completed session was counted");
    }
}
