//! The accelerator node: accept a job over TCP, run the streaming
//! preprocessor, stream results back. Speaks both protocols — the
//! leader's first data frame decides: `FusedChunk` runs the single-pass
//! fused dataflow (results stream back while the dataset is still
//! arriving, once over the wire), `Pass1Chunk` runs the two-pass
//! protocol (required by the cluster leader-merge).

use std::net::{TcpListener, TcpStream};

use crate::Result;

use super::protocol::{self, RunStats, Tag};
use super::stream::StreamingPreprocessor;

/// Serve a single connection on `listener` and return after the job
/// completes. The caller loops for a long-lived service.
pub fn serve_one(listener: &TcpListener) -> Result<RunStats> {
    let (stream, _addr) = listener.accept()?;
    handle(stream)
}

/// Serve `n` jobs then return (used by tests and the example binary).
pub fn serve_n(listener: &TcpListener, n: usize) -> Result<()> {
    for _ in 0..n {
        serve_one(listener)?;
    }
    Ok(())
}

fn handle(stream: TcpStream) -> Result<RunStats> {
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::with_capacity(1 << 20, stream.try_clone()?);
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream);

    // First frame must be the job header. Decoding it re-parses (and
    // re-validates) the per-column spec; compiling it against the job's
    // schema is the worker-side planning step — both fail here, before
    // any data frame is accepted.
    let (tag, payload) = protocol::read_frame(&mut reader)?;
    anyhow::ensure!(tag == Tag::Job, "expected Job frame, got {tag:?}");
    let job = protocol::Job::decode(&payload)?;
    // Worker posture: decode wire chunks with every local core (the
    // same row-sharded path the engine uses; output is bit-identical
    // to the sequential decode).
    let decode = crate::pipeline::DecodeOptions {
        threads: crate::decode::shard::default_threads(),
        swar: true,
    };
    let mut sp =
        StreamingPreprocessor::with_decode_options(&job.spec, job.schema, job.format, decode)?;

    loop {
        let (tag, payload) = protocol::read_frame(&mut reader)?;
        match tag {
            Tag::FusedChunk => {
                // Single-pass protocol: observe + apply in one scan,
                // stream the rows straight back.
                let rows = sp.fused_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(&mut writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::FusedEnd => {
                let rows = sp.fused_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(&mut writer, Tag::ResultChunk, &packed)?;
                }
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                };
                protocol::write_frame(&mut writer, Tag::ResultEnd, &stats.encode())?;
                use std::io::Write as _;
                writer.flush()?;
                return Ok(stats);
            }
            Tag::Pass1Chunk => sp.pass1_chunk(&payload)?,
            Tag::Pass1End => sp.pass1_end()?,
            Tag::VocabSync => {
                // Cluster mode: ship sub-vocabularies for the global
                // merge (the one synchronization point of the sharded
                // deployment — paper §2.4's merge, moved to the leader).
                let dump = protocol::pack_vocabs(&sp.export_vocabs());
                protocol::write_frame(&mut writer, Tag::VocabDump, &dump)?;
                use std::io::Write as _;
                writer.flush()?;
            }
            Tag::VocabLoad => {
                sp.import_vocabs(protocol::unpack_vocabs(&payload)?)?;
            }
            Tag::Pass2Chunk => {
                // Stream results back immediately — the pipelined overlap
                // of Fig. 7d.
                let rows = sp.pass2_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(&mut writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::Pass2End => {
                let rows = sp.pass2_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(&mut writer, Tag::ResultChunk, &packed)?;
                }
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                };
                protocol::write_frame(&mut writer, Tag::ResultEnd, &stats.encode())?;
                use std::io::Write as _;
                writer.flush()?;
                return Ok(stats);
            }
            other => anyhow::bail!("unexpected frame {other:?} from leader"),
        }
    }
}
