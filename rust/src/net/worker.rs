//! The accelerator node: accept a job over TCP, run the streaming
//! preprocessor, stream results back. Speaks all three protocols — the
//! first frame decides: a [`Tag::Job`] header opens a batch session
//! where the next data frame picks the dataflow (`FusedChunk` runs the
//! single-pass fused dataflow, `Pass1Chunk` the two-pass protocol the
//! cluster leader-merge requires); a [`Tag::ServeJob`] header opens an
//! online serving session against a frozen artifact
//! ([`crate::net::serve`]).
//!
//! Error posture: any session error — malformed frame, bad job header,
//! decode failure — is reported to the peer as a [`Tag::ErrorReply`]
//! frame carrying the message, then the connection closes cleanly. A
//! hostile or buggy client costs the worker one connection, never the
//! process.

use std::net::{TcpListener, TcpStream};

use crate::Result;

use super::protocol::{self, RunStats, Tag};
use super::serve;
use super::stream::StreamingPreprocessor;

/// Serve a single connection on `listener` and return after the job
/// completes. The caller loops for a long-lived service.
pub fn serve_one(listener: &TcpListener) -> Result<RunStats> {
    let (stream, _addr) = listener.accept()?;
    handle(stream)
}

/// Serve `n` jobs then return (used by tests and the example binary).
pub fn serve_n(listener: &TcpListener, n: usize) -> Result<()> {
    for _ in 0..n {
        serve_one(listener)?;
    }
    Ok(())
}

/// Accept connections forever. A failed session is logged and the
/// worker moves to the next connection — the long-lived posture for a
/// serving deployment.
pub fn serve_forever(listener: &TcpListener) -> ! {
    loop {
        match serve_one(listener) {
            Ok(stats) => eprintln!("session done: {} rows", stats.rows),
            Err(e) => eprintln!("session failed: {e:#}"),
        }
    }
}

fn handle(stream: TcpStream) -> Result<RunStats> {
    stream.set_nodelay(true)?;
    let mut reader = std::io::BufReader::with_capacity(1 << 20, stream.try_clone()?);
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream);

    match session(&mut reader, &mut writer) {
        Ok(stats) => Ok(stats),
        Err(e) => {
            // Best effort: tell the peer why before hanging up. The
            // connection may already be gone — that must not mask the
            // original error.
            use std::io::Write as _;
            let _ = protocol::write_frame(&mut writer, Tag::ErrorReply, e.to_string().as_bytes());
            let _ = writer.flush();
            Err(e)
        }
    }
}

/// One full session: dispatch on the header frame, then run the chosen
/// protocol to completion. Every error propagates to [`handle`], which
/// turns it into an [`Tag::ErrorReply`] frame.
fn session(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
) -> Result<RunStats> {
    // First frame must be a job header. Decoding it re-parses (and
    // re-validates) the per-column spec; compiling it against the job's
    // schema is the worker-side planning step — both fail here, before
    // any data frame is accepted.
    let (tag, payload) = protocol::read_frame(reader)?;
    match tag {
        Tag::Job => batch_session(reader, writer, protocol::Job::decode(&payload)?),
        Tag::ServeJob => {
            let job = serve::ServeJob::decode(&payload)?;
            let report = serve::run_session(reader, writer, &job)?;
            Ok(RunStats {
                rows: report.rows,
                vocab_entries: job.artifact.total_entries() as u64,
            })
        }
        other => anyhow::bail!("expected Job or ServeJob frame, got {other:?}"),
    }
}

fn batch_session(
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut std::io::BufWriter<TcpStream>,
    job: protocol::Job,
) -> Result<RunStats> {
    // Worker posture: decode wire chunks with every local core (the
    // same row-sharded path the engine uses; output is bit-identical
    // to the sequential decode).
    let decode = crate::pipeline::DecodeOptions {
        threads: crate::decode::shard::default_threads(),
        swar: true,
    };
    let mut sp =
        StreamingPreprocessor::with_decode_options(&job.spec, job.schema, job.format, decode)?;

    loop {
        let (tag, payload) = protocol::read_frame(reader)?;
        match tag {
            Tag::FusedChunk => {
                // Single-pass protocol: observe + apply in one scan,
                // stream the rows straight back.
                let rows = sp.fused_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::FusedEnd => {
                let rows = sp.fused_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                };
                protocol::write_frame(writer, Tag::ResultEnd, &stats.encode())?;
                use std::io::Write as _;
                writer.flush()?;
                return Ok(stats);
            }
            Tag::Pass1Chunk => sp.pass1_chunk(&payload)?,
            Tag::Pass1End => sp.pass1_end()?,
            Tag::VocabSync => {
                // Cluster mode: ship sub-vocabularies for the global
                // merge (the one synchronization point of the sharded
                // deployment — paper §2.4's merge, moved to the leader).
                let dump = protocol::pack_vocabs(&sp.export_vocabs());
                protocol::write_frame(writer, Tag::VocabDump, &dump)?;
                use std::io::Write as _;
                writer.flush()?;
            }
            Tag::VocabLoad => {
                sp.import_vocabs(protocol::unpack_vocabs(&payload)?)?;
            }
            Tag::Pass2Chunk => {
                // Stream results back immediately — the pipelined overlap
                // of Fig. 7d.
                let rows = sp.pass2_chunk(&payload)?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
            }
            Tag::Pass2End => {
                let rows = sp.pass2_end()?;
                if !rows.is_empty() {
                    let packed = protocol::pack_rows(&rows, job.schema);
                    protocol::write_frame(writer, Tag::ResultChunk, &packed)?;
                }
                let stats = RunStats {
                    rows: sp.rows_seen().1 as u64,
                    vocab_entries: sp.vocab_entries() as u64,
                };
                protocol::write_frame(writer, Tag::ResultEnd, &stats.encode())?;
                use std::io::Write as _;
                writer.flush()?;
                return Ok(stats);
            }
            other => anyhow::bail!("unexpected frame {other:?} from leader"),
        }
    }
}
