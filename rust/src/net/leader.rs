//! The leader: streams the dataset to a PIPER worker and collects the
//! preprocessed rows as they come back. Under the fused strategy (the
//! single-node default) the dataset crosses the wire **once** and the
//! source never rewinds; under two-pass it is streamed twice (the two
//! vocabulary loops), which the cluster leader-merge path requires.
//!
//! Failure posture: every socket carries the [`NetConfig`] I/O deadline
//! and the whole exchange runs under the job's wall-clock budget
//! ([`JobClock`]) — a dead or wedged worker surfaces as a typed
//! [`NetError`] (`Timeout` / `PeerGone`), and a worker-reported
//! `ErrorReply` as [`NetError::JobFailed`] carrying the worker's
//! address and its own reason string. Single-worker runs don't retry
//! (there is no second worker to rotate to) — split-level retry lives
//! in [`super::cluster`].

use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::pipeline::{ExecStrategy, MemorySource, Source};
use crate::Result;

use super::protocol::{self, Job, NetError, RunStats, Tag};
use super::{JobClock, NetConfig};
#[cfg(test)]
use super::stream::WireFormat;

/// Result of a leader-side run.
#[derive(Debug)]
pub struct LeaderRun {
    pub processed: ProcessedColumns,
    pub stats: RunStats,
    /// Measured wallclock of the whole exchange on loopback.
    pub wallclock: Duration,
}

/// Stream `raw` to the worker at `addr` and collect results.
///
/// Convenience wrapper over [`run_leader_source`] for in-memory buffers.
pub fn run_leader(
    addr: &str,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<LeaderRun> {
    let mut source = MemorySource::new(raw, job.format.into());
    run_leader_source(addr, job, &mut source, chunk_size, strategy)
}

/// [`run_leader`] with explicit fault-tolerance knobs.
pub fn run_leader_cfg(
    addr: &str,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    strategy: ExecStrategy,
    cfg: &NetConfig,
) -> Result<LeaderRun> {
    let mut source = MemorySource::new(raw, job.format.into());
    run_leader_source_cfg(addr, job, &mut source, chunk_size, strategy, cfg)
}

/// Stream a [`Source`] to the worker at `addr` and collect results
/// under the default [`NetConfig`] (30 s I/O deadline, no job budget).
pub fn run_leader_source(
    addr: &str,
    job: &Job,
    source: &mut dyn Source,
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<LeaderRun> {
    run_leader_source_cfg(addr, job, source, chunk_size, strategy, &NetConfig::default())
}

/// Stream a [`Source`] to the worker at `addr` and collect results. The
/// leader holds one chunk at a time — submitting a file-backed dataset
/// never loads it into memory.
///
/// Fused: one pass of `FusedChunk` frames; the source never rewinds (so
/// one-shot sources work) and results stream back while the dataset is
/// still going out. Two-pass: `Pass1Chunk`* then [`Source::reset`] then
/// `Pass2Chunk`* — requires [`Source::can_rewind`].
///
/// Emitting reads interleave with writes: a reader thread drains
/// ResultChunks while the main thread keeps sending, so the socket can't
/// deadlock on full buffers and the measured time reflects true
/// streaming overlap. If the send path and the collector both fail, the
/// collector's error wins when it carries the worker's own
/// [`NetError::JobFailed`] reason — a send-side broken pipe is usually
/// just the echo of the worker aborting the session.
///
/// With [`NetConfig::leader_window`] >= 2 each pass additionally reads
/// ahead: a prefetch thread pulls source chunks while this thread
/// writes frames, overlapping disk reads with the network send (the
/// `submit`-side analogue of the engine's `pipeline_depth`). The wire
/// protocol and the worker are unchanged.
pub fn run_leader_source_cfg(
    addr: &str,
    job: &Job,
    source: &mut dyn Source,
    chunk_size: usize,
    strategy: ExecStrategy,
    cfg: &NetConfig,
) -> Result<LeaderRun> {
    anyhow::ensure!(
        source.format() == job.format.into(),
        "source yields {:?} but the job wants {:?}",
        source.format(),
        job.format
    );
    if strategy == ExecStrategy::TwoPass {
        anyhow::ensure!(
            source.can_rewind(),
            "two-pass submission needs a rewindable source; use the fused strategy"
        );
    }
    let start = Instant::now();
    let clock = cfg.clock();
    let stream = super::connect(addr, cfg.io_timeout, &clock)?;
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream.try_clone()?);

    protocol::write_frame(&mut writer, Tag::Job, &job.encode())?;

    if strategy == ExecStrategy::TwoPass {
        // Pass 1 produces no results, so no reader is needed yet.
        stream_pass(
            &mut writer,
            &mut *source,
            chunk_size,
            Tag::Pass1Chunk,
            &clock,
            cfg.leader_window,
            "sending pass 1",
        )?;
        protocol::write_frame(&mut writer, Tag::Pass1End, &[])?;
        source.reset()?;
    }

    // Reader thread: collect results while the emitting pass streams out.
    let schema = job.schema;
    let reader_stream = stream.try_clone()?;
    let worker_addr = addr.to_string();
    let collector = std::thread::spawn(move || -> Result<(ProcessedColumns, RunStats)> {
        let mut reader = std::io::BufReader::with_capacity(1 << 20, reader_stream);
        let mut cols = ProcessedColumns::with_schema(schema);
        loop {
            clock.check("collecting results")?;
            let (tag, payload) = protocol::read_frame(&mut reader)?;
            match tag {
                Tag::ResultChunk => {
                    for row in protocol::unpack_rows(&payload, schema)? {
                        cols.push_row(&row);
                    }
                }
                Tag::ResultEnd => {
                    let stats = RunStats::decode(&payload)?;
                    return Ok((cols, stats));
                }
                Tag::ErrorReply => {
                    anyhow::bail!(NetError::JobFailed {
                        worker: worker_addr,
                        reason: String::from_utf8_lossy(&payload).into_owned(),
                    })
                }
                other => anyhow::bail!(NetError::Malformed {
                    what: format!("unexpected frame {other:?} from worker"),
                }),
            }
        }
    });

    let sent = (|| -> Result<()> {
        let (chunk_tag, end_tag) = match strategy {
            ExecStrategy::Fused => (Tag::FusedChunk, Tag::FusedEnd),
            ExecStrategy::TwoPass => (Tag::Pass2Chunk, Tag::Pass2End),
        };
        stream_pass(
            &mut writer,
            &mut *source,
            chunk_size,
            chunk_tag,
            &clock,
            cfg.leader_window,
            "sending the emitting pass",
        )?;
        protocol::write_frame(&mut writer, end_tag, &[])?;
        use std::io::Write as _;
        writer.flush()?;
        Ok(())
    })();

    // Join the collector even when the send path failed: a broken send
    // is usually the echo of a worker abort, and the collector holds
    // the worker's ErrorReply (the root cause) in that case.
    let collected = collector
        .join()
        .map_err(|_| anyhow::anyhow!("collector thread panicked"))?;
    let (processed, stats) = match (sent, collected) {
        (_, Ok(out)) => out,
        (Err(send_err), Err(collect_err)) => {
            if matches!(NetError::of(&collect_err), Some(NetError::JobFailed { .. })) {
                return Err(collect_err);
            }
            return Err(send_err);
        }
        (Ok(()), Err(collect_err)) => return Err(collect_err),
    };
    Ok(LeaderRun { processed, stats, wallclock: start.elapsed() })
}

/// Stream one pass of `source` as `tag` frames onto `writer`.
///
/// `window <= 1` is the classic sequential loop: one reused chunk
/// buffer, read then send, so the leader's resident raw-input memory is
/// a single chunk regardless of dataset size. `window >= 2` spawns a
/// scoped prefetch thread that reads up to `window - 1` chunks ahead of
/// the socket through a bounded channel, with consumed buffers
/// recycling back over a pool lane — peak leader memory becomes
/// `window × chunk_size`, still dataset-size-independent. The job
/// clock is checked per frame on the writing side either way. Error
/// precedence matches the engine's streaming loop: a source (prefetch)
/// error explains any downstream write error and wins.
fn stream_pass<W: std::io::Write>(
    writer: &mut W,
    source: &mut dyn Source,
    chunk_size: usize,
    tag: Tag,
    clock: &JobClock,
    window: usize,
    what: &'static str,
) -> Result<()> {
    let chunk_size = chunk_size.max(1);
    if window <= 1 {
        let mut chunk = Vec::new();
        while source.next_chunk(chunk_size, &mut chunk)? {
            clock.check(what)?;
            protocol::write_frame(writer, tag, &chunk)?;
        }
        return Ok(());
    }
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<u8>>(window - 1);
        let (pool_tx, pool_rx) = std::sync::mpsc::channel::<Vec<u8>>();
        let producer = scope.spawn(move || -> Result<()> {
            loop {
                let mut buf = pool_rx.try_recv().unwrap_or_default();
                if !source.next_chunk(chunk_size, &mut buf)? {
                    break;
                }
                if tx.send(buf).is_err() {
                    break; // writer bailed; its error surfaces below
                }
            }
            Ok(())
        });
        let mut write_err: Option<anyhow::Error> = None;
        for chunk in &rx {
            let step = clock
                .check(what)
                .and_then(|()| protocol::write_frame(writer, tag, &chunk));
            let _ = pool_tx.send(chunk); // recycle the buffer
            if let Err(e) = step {
                write_err = Some(e);
                break;
            }
        }
        drop(rx); // unblock the prefetcher if we bailed early
        let produced = producer
            .join()
            .map_err(|_| anyhow::anyhow!("leader prefetch thread panicked"))?;
        match (produced, write_err) {
            // A source error explains any downstream write failure.
            (Err(e), _) => Err(e),
            (Ok(()), Some(e)) => Err(e),
            (Ok(()), None) => Ok(()),
        }
    })
}

/// Spawn a worker on an ephemeral loopback port, run the leader against
/// it (fused — the single-node default), and return the result — the
/// one-call path used by examples and tests.
pub fn run_loopback(job: &Job, raw: &[u8], chunk_size: usize) -> Result<LeaderRun> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let worker = std::thread::spawn(move || super::worker::serve_one(&listener));
    let run = run_leader(&addr.to_string(), job, raw, chunk_size, ExecStrategy::Fused)?;
    worker
        .join()
        .map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    #[test]
    fn loopback_utf8_matches_cpu_baseline() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let run = run_loopback(&job, &raw, 4096).unwrap();

        let baseline = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        );
        assert_eq!(run.processed, baseline.processed);
        assert_eq!(run.stats.rows, 200);
    }

    #[test]
    fn loopback_binary_works() {
        let ds = SynthDataset::generate(SynthConfig::small(120));
        let m = Modulus::new(101);
        let raw = binary::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Binary);
        let run = run_loopback(&job, &raw, 333).unwrap();
        assert_eq!(run.processed.num_rows(), 120);
        assert!(run.stats.vocab_entries > 0);
    }

    /// A heterogeneous per-column job over real TCP equals the spec's
    /// reference interpreter — the wire handshake carries the whole
    /// program set, not just one modulus.
    #[test]
    fn loopback_heterogeneous_spec_matches_reference() {
        let ds = SynthDataset::generate(SynthConfig::small(210));
        let spec = crate::ops::PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             dense[*]: neg2zero|log; \
             dense[2]: clip:0:100|bucketize:1:10:100",
        )
        .unwrap();
        let want = spec.execute(&ds.rows, ds.schema()).unwrap();
        let raw = utf8::encode_dataset(&ds);
        let job =
            Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
        let run = run_loopback(&job, &raw, 2048).unwrap();
        assert_eq!(run.processed, want);
        assert_eq!(run.stats.rows, 210);
    }

    /// Both wire strategies against a real worker must produce
    /// bit-identical rows and stats; the fused run sends the dataset
    /// over the wire once, the two-pass run twice.
    #[test]
    fn fused_wire_run_matches_two_pass_wire_run() {
        let ds = SynthDataset::generate(SynthConfig::small(180));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);

        let run_with = |strategy: ExecStrategy| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let worker = std::thread::spawn(move || super::super::worker::serve_one(&listener));
            let run = run_leader(&addr.to_string(), &job, &raw, 1024, strategy).unwrap();
            worker.join().unwrap().unwrap();
            run
        };
        let fused = run_with(ExecStrategy::Fused);
        let two = run_with(ExecStrategy::TwoPass);
        assert_eq!(fused.processed, two.processed);
        assert_eq!(fused.stats, two.stats);
    }

    /// The leader's read-ahead window must be invisible on the wire:
    /// same rows, same stats, under both strategies (each pass
    /// prefetches), even with tiny chunks forcing deep queue cycling.
    #[test]
    fn leader_read_ahead_window_matches_sequential() {
        let ds = SynthDataset::generate(SynthConfig::small(160));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let run_with = |window: usize, strategy: ExecStrategy| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let worker = std::thread::spawn(move || super::super::worker::serve_one(&listener));
            let cfg = NetConfig { leader_window: window, ..NetConfig::default() };
            let run =
                run_leader_cfg(&addr.to_string(), &job, &raw, 64, strategy, &cfg).unwrap();
            worker.join().unwrap().unwrap();
            run
        };
        let seq = run_with(1, ExecStrategy::Fused);
        let pre = run_with(4, ExecStrategy::Fused);
        assert_eq!(pre.processed, seq.processed, "read-ahead must not change output");
        assert_eq!(pre.stats, seq.stats);
        let two = run_with(4, ExecStrategy::TwoPass);
        assert_eq!(two.processed, seq.processed, "both passes must prefetch correctly");
    }

    #[test]
    fn tiny_chunks_stress_framing() {
        let ds = SynthDataset::generate(SynthConfig::small(30));
        let m = Modulus::new(53);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let a = run_loopback(&job, &raw, 7).unwrap();
        let b = run_loopback(&job, &raw, 64 * 1024).unwrap();
        assert_eq!(a.processed, b.processed);
    }

    /// A worker-side failure must surface as a typed
    /// [`NetError::JobFailed`] carrying the worker's address and the
    /// worker's own reason — not a generic string (PR 6 satellite,
    /// strengthened to assert *content*).
    #[test]
    fn worker_error_reply_surfaces_as_typed_job_failed() {
        let ds = SynthDataset::generate(SynthConfig::small(10));
        let raw = utf8::encode_dataset(&ds);
        // A spec whose selector is outside the schema: the worker's
        // planning step rejects it after the Job frame.
        let spec =
            crate::ops::PipelineSpec::parse("sparse[40]: modulus:7|genvocab|applyvocab").unwrap();
        let job =
            Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
        let err = run_loopback(&job, &raw, 1024).unwrap_err();
        match NetError::of(&err) {
            Some(NetError::JobFailed { worker, reason }) => {
                assert!(worker.starts_with("127.0.0.1:"), "worker address, got {worker}");
                assert!(
                    reason.contains("selector") || reason.contains("sparse"),
                    "worker's own planning error must travel: {reason}"
                );
            }
            other => panic!("expected JobFailed, got {other:?}: {err:#}"),
        }
    }

    /// A deadline of ~zero must fail fast with a typed Timeout, not
    /// hang — the whole point of the budget.
    #[test]
    fn exhausted_job_deadline_is_a_typed_timeout() {
        let ds = SynthDataset::generate(SynthConfig::small(10));
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), Modulus::new(97), WireFormat::Utf8);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Nobody accepts: with an (effectively) expired budget the
        // connect must be refused by the clock before it blocks.
        let cfg = NetConfig { job_deadline: Some(Duration::ZERO), ..NetConfig::default() };
        let err = run_leader_cfg(
            &addr.to_string(), &job, &raw, 1024, ExecStrategy::Fused, &cfg,
        )
        .unwrap_err();
        assert!(
            matches!(NetError::of(&err), Some(NetError::Timeout { .. })),
            "{err:#}"
        );
        drop(listener);
    }
}
