//! The leader: streams the dataset to a PIPER worker and collects the
//! preprocessed rows as they come back. Under the fused strategy (the
//! single-node default) the dataset crosses the wire **once** and the
//! source never rewinds; under two-pass it is streamed twice (the two
//! vocabulary loops), which the cluster leader-merge path requires.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::pipeline::{ExecStrategy, MemorySource, Source};
use crate::Result;

use super::protocol::{self, Job, RunStats, Tag};
#[cfg(test)]
use super::stream::WireFormat;

/// Result of a leader-side run.
#[derive(Debug)]
pub struct LeaderRun {
    pub processed: ProcessedColumns,
    pub stats: RunStats,
    /// Measured wallclock of the whole exchange on loopback.
    pub wallclock: Duration,
}

/// Stream `raw` to the worker at `addr` and collect results.
///
/// Convenience wrapper over [`run_leader_source`] for in-memory buffers.
pub fn run_leader(
    addr: &str,
    job: &Job,
    raw: &[u8],
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<LeaderRun> {
    let mut source = MemorySource::new(raw, job.format.into());
    run_leader_source(addr, job, &mut source, chunk_size, strategy)
}

/// Stream a [`Source`] to the worker at `addr` and collect results. The
/// leader holds one chunk at a time — submitting a file-backed dataset
/// never loads it into memory.
///
/// Fused: one pass of `FusedChunk` frames; the source never rewinds (so
/// one-shot sources work) and results stream back while the dataset is
/// still going out. Two-pass: `Pass1Chunk`* then [`Source::reset`] then
/// `Pass2Chunk`* — requires [`Source::can_rewind`].
///
/// Emitting reads interleave with writes: a reader thread drains
/// ResultChunks while the main thread keeps sending, so the socket can't
/// deadlock on full buffers and the measured time reflects true
/// streaming overlap.
pub fn run_leader_source(
    addr: &str,
    job: &Job,
    source: &mut dyn Source,
    chunk_size: usize,
    strategy: ExecStrategy,
) -> Result<LeaderRun> {
    anyhow::ensure!(
        source.format() == job.format.into(),
        "source yields {:?} but the job wants {:?}",
        source.format(),
        job.format
    );
    if strategy == ExecStrategy::TwoPass {
        anyhow::ensure!(
            source.can_rewind(),
            "two-pass submission needs a rewindable source; use the fused strategy"
        );
    }
    let start = Instant::now();
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream.try_clone()?);

    protocol::write_frame(&mut writer, Tag::Job, &job.encode())?;
    // One reused chunk buffer per submission — the leader's resident
    // raw-input memory, regardless of dataset size.
    let mut chunk = Vec::new();

    if strategy == ExecStrategy::TwoPass {
        // Pass 1 produces no results, so no reader is needed yet.
        while source.next_chunk(chunk_size.max(1), &mut chunk)? {
            protocol::write_frame(&mut writer, Tag::Pass1Chunk, &chunk)?;
        }
        protocol::write_frame(&mut writer, Tag::Pass1End, &[])?;
        source.reset()?;
    }

    // Reader thread: collect results while the emitting pass streams out.
    let schema = job.schema;
    let reader_stream = stream.try_clone()?;
    let collector = std::thread::spawn(move || -> Result<(ProcessedColumns, RunStats)> {
        let mut reader = std::io::BufReader::with_capacity(1 << 20, reader_stream);
        let mut cols = ProcessedColumns::with_schema(schema);
        loop {
            let (tag, payload) = protocol::read_frame(&mut reader)?;
            match tag {
                Tag::ResultChunk => {
                    for row in protocol::unpack_rows(&payload, schema)? {
                        cols.push_row(&row);
                    }
                }
                Tag::ResultEnd => {
                    let stats = RunStats::decode(&payload)?;
                    return Ok((cols, stats));
                }
                Tag::ErrorReply => {
                    anyhow::bail!("worker error: {}", String::from_utf8_lossy(&payload))
                }
                other => anyhow::bail!("unexpected frame {other:?} from worker"),
            }
        }
    });

    let (chunk_tag, end_tag) = match strategy {
        ExecStrategy::Fused => (Tag::FusedChunk, Tag::FusedEnd),
        ExecStrategy::TwoPass => (Tag::Pass2Chunk, Tag::Pass2End),
    };
    while source.next_chunk(chunk_size.max(1), &mut chunk)? {
        protocol::write_frame(&mut writer, chunk_tag, &chunk)?;
    }
    protocol::write_frame(&mut writer, end_tag, &[])?;
    use std::io::Write as _;
    writer.flush()?;

    let (processed, stats) = collector
        .join()
        .map_err(|_| anyhow::anyhow!("collector thread panicked"))??;
    Ok(LeaderRun { processed, stats, wallclock: start.elapsed() })
}

/// Spawn a worker on an ephemeral loopback port, run the leader against
/// it (fused — the single-node default), and return the result — the
/// one-call path used by examples and tests.
pub fn run_loopback(job: &Job, raw: &[u8], chunk_size: usize) -> Result<LeaderRun> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let worker = std::thread::spawn(move || super::worker::serve_one(&listener));
    let run = run_leader(&addr.to_string(), job, raw, chunk_size, ExecStrategy::Fused)?;
    worker
        .join()
        .map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    #[test]
    fn loopback_utf8_matches_cpu_baseline() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let run = run_loopback(&job, &raw, 4096).unwrap();

        let baseline = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        );
        assert_eq!(run.processed, baseline.processed);
        assert_eq!(run.stats.rows, 200);
    }

    #[test]
    fn loopback_binary_works() {
        let ds = SynthDataset::generate(SynthConfig::small(120));
        let m = Modulus::new(101);
        let raw = binary::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Binary);
        let run = run_loopback(&job, &raw, 333).unwrap();
        assert_eq!(run.processed.num_rows(), 120);
        assert!(run.stats.vocab_entries > 0);
    }

    /// A heterogeneous per-column job over real TCP equals the spec's
    /// reference interpreter — the wire handshake carries the whole
    /// program set, not just one modulus.
    #[test]
    fn loopback_heterogeneous_spec_matches_reference() {
        let ds = SynthDataset::generate(SynthConfig::small(210));
        let spec = crate::ops::PipelineSpec::parse(
            "sparse[*]: modulus:997|genvocab|applyvocab; \
             sparse[0..4]: modulus:101|genvocab|applyvocab; \
             dense[*]: neg2zero|log; \
             dense[2]: clip:0:100|bucketize:1:10:100",
        )
        .unwrap();
        let want = spec.execute(&ds.rows, ds.schema()).unwrap();
        let raw = utf8::encode_dataset(&ds);
        let job = Job { schema: ds.schema(), spec, format: WireFormat::Utf8 };
        let run = run_loopback(&job, &raw, 2048).unwrap();
        assert_eq!(run.processed, want);
        assert_eq!(run.stats.rows, 210);
    }

    /// Both wire strategies against a real worker must produce
    /// bit-identical rows and stats; the fused run sends the dataset
    /// over the wire once, the two-pass run twice.
    #[test]
    fn fused_wire_run_matches_two_pass_wire_run() {
        let ds = SynthDataset::generate(SynthConfig::small(180));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);

        let run_with = |strategy: ExecStrategy| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let worker = std::thread::spawn(move || super::super::worker::serve_one(&listener));
            let run = run_leader(&addr.to_string(), &job, &raw, 1024, strategy).unwrap();
            worker.join().unwrap().unwrap();
            run
        };
        let fused = run_with(ExecStrategy::Fused);
        let two = run_with(ExecStrategy::TwoPass);
        assert_eq!(fused.processed, two.processed);
        assert_eq!(fused.stats, two.stats);
    }

    #[test]
    fn tiny_chunks_stress_framing() {
        let ds = SynthDataset::generate(SynthConfig::small(30));
        let m = Modulus::new(53);
        let raw = utf8::encode_dataset(&ds);
        let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
        let a = run_loopback(&job, &raw, 7).unwrap();
        let b = run_loopback(&job, &raw, 64 * 1024).unwrap();
        assert_eq!(a.processed, b.processed);
    }
}
