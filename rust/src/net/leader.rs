//! The leader: streams the dataset to a PIPER worker twice (the two
//! loops) and collects the preprocessed rows as they come back.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::row::ProcessedColumns;
use crate::pipeline::{MemorySource, Source};
use crate::Result;

use super::protocol::{self, Job, RunStats, Tag};
#[cfg(test)]
use super::stream::WireFormat;

/// Result of a leader-side run.
#[derive(Debug)]
pub struct LeaderRun {
    pub processed: ProcessedColumns,
    pub stats: RunStats,
    /// Measured wallclock of the whole exchange on loopback.
    pub wallclock: Duration,
}

/// Stream `raw` (twice) to the worker at `addr` and collect results.
///
/// Convenience wrapper over [`run_leader_source`] for in-memory buffers.
pub fn run_leader(
    addr: &str,
    job: Job,
    raw: &[u8],
    chunk_size: usize,
) -> Result<LeaderRun> {
    let mut source = MemorySource::new(raw, job.format.into());
    run_leader_source(addr, job, &mut source, chunk_size)
}

/// Stream a [`Source`] (twice, via [`Source::reset`]) to the worker at
/// `addr` and collect results. The leader holds one chunk at a time —
/// submitting a file-backed dataset never loads it into memory.
///
/// Pass 2 reads interleaved with writes: a reader thread drains
/// ResultChunks while the main thread keeps sending, so the socket can't
/// deadlock on full buffers and the measured time reflects true
/// streaming overlap.
pub fn run_leader_source(
    addr: &str,
    job: Job,
    source: &mut dyn Source,
    chunk_size: usize,
) -> Result<LeaderRun> {
    anyhow::ensure!(
        source.format() == job.format.into(),
        "source yields {:?} but the job wants {:?}",
        source.format(),
        job.format
    );
    let start = Instant::now();
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut writer = std::io::BufWriter::with_capacity(1 << 20, stream.try_clone()?);

    protocol::write_frame(&mut writer, Tag::Job, &job.encode())?;
    // One reused chunk buffer per submission — the leader's resident
    // raw-input memory, regardless of dataset size.
    let mut chunk = Vec::new();
    while source.next_chunk(chunk_size.max(1), &mut chunk)? {
        protocol::write_frame(&mut writer, Tag::Pass1Chunk, &chunk)?;
    }
    protocol::write_frame(&mut writer, Tag::Pass1End, &[])?;
    source.reset()?;

    // Reader thread: collect results while pass 2 streams out.
    let schema = job.schema;
    let reader_stream = stream.try_clone()?;
    let collector = std::thread::spawn(move || -> Result<(ProcessedColumns, RunStats)> {
        let mut reader = std::io::BufReader::with_capacity(1 << 20, reader_stream);
        let mut cols = ProcessedColumns::with_schema(schema);
        loop {
            let (tag, payload) = protocol::read_frame(&mut reader)?;
            match tag {
                Tag::ResultChunk => {
                    for row in protocol::unpack_rows(&payload, schema)? {
                        cols.push_row(&row);
                    }
                }
                Tag::ResultEnd => {
                    let stats = RunStats::decode(&payload)?;
                    return Ok((cols, stats));
                }
                other => anyhow::bail!("unexpected frame {other:?} from worker"),
            }
        }
    });

    while source.next_chunk(chunk_size.max(1), &mut chunk)? {
        protocol::write_frame(&mut writer, Tag::Pass2Chunk, &chunk)?;
    }
    protocol::write_frame(&mut writer, Tag::Pass2End, &[])?;
    use std::io::Write as _;
    writer.flush()?;

    let (processed, stats) = collector
        .join()
        .map_err(|_| anyhow::anyhow!("collector thread panicked"))??;
    Ok(LeaderRun { processed, stats, wallclock: start.elapsed() })
}

/// Spawn a worker on an ephemeral loopback port, run the leader against
/// it, and return the result — the one-call path used by examples and
/// tests.
pub fn run_loopback(job: Job, raw: &[u8], chunk_size: usize) -> Result<LeaderRun> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let worker = std::thread::spawn(move || super::worker::serve_one(&listener));
    let run = run_leader(&addr.to_string(), job, raw, chunk_size)?;
    worker
        .join()
        .map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{binary, synth::SynthConfig, utf8, SynthDataset};
    use crate::ops::Modulus;

    #[test]
    fn loopback_utf8_matches_cpu_baseline() {
        let ds = SynthDataset::generate(SynthConfig::small(200));
        let m = Modulus::new(997);
        let raw = utf8::encode_dataset(&ds);
        let job = Job { schema: ds.schema(), modulus: m, format: WireFormat::Utf8 };
        let run = run_loopback(job, &raw, 4096).unwrap();

        let baseline = crate::cpu_baseline::run(
            &crate::cpu_baseline::BaselineConfig::new(
                crate::cpu_baseline::ConfigKind::I,
                2,
                m,
            ),
            &raw,
        );
        assert_eq!(run.processed, baseline.processed);
        assert_eq!(run.stats.rows, 200);
    }

    #[test]
    fn loopback_binary_works() {
        let ds = SynthDataset::generate(SynthConfig::small(120));
        let m = Modulus::new(101);
        let raw = binary::encode_dataset(&ds);
        let job = Job { schema: ds.schema(), modulus: m, format: WireFormat::Binary };
        let run = run_loopback(job, &raw, 333).unwrap();
        assert_eq!(run.processed.num_rows(), 120);
        assert!(run.stats.vocab_entries > 0);
    }

    #[test]
    fn tiny_chunks_stress_framing() {
        let ds = SynthDataset::generate(SynthConfig::small(30));
        let m = Modulus::new(53);
        let raw = utf8::encode_dataset(&ds);
        let job = Job { schema: ds.schema(), modulus: m, format: WireFormat::Utf8 };
        let a = run_loopback(job, &raw, 7).unwrap();
        let b = run_loopback(job, &raw, 64 * 1024).unwrap();
        assert_eq!(a.processed, b.processed);
    }
}
