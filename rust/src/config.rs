//! Config system: `key = value` files + CLI-style `key=value` overrides.
//!
//! No serde dependency is available offline, so this is a small,
//! well-tested hand parser. Every experiment knob (rows, vocab size,
//! backend, threads, mode, decode width, seed, ...) is settable from a
//! file (`--config path`) and overridable on the command line, which is
//! what the launcher (`piper` binary) and the bench harness build on.

use crate::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// An ordered key→value map with typed accessors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a config file: `key = value` lines, `#` comments, blank
    /// lines ignored. Later keys override earlier ones.
    pub fn from_str_content(content: &str) -> Result<Self> {
        let mut cfg = Config::new();
        for (lineno, raw_line) in content.lines().enumerate() {
            let line = raw_line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("config line {}: expected `key = value`, got `{raw_line}`",
                    lineno + 1)
            })?;
            cfg.set(k.trim(), v.trim());
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let content = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        Self::from_str_content(&content)
    }

    /// Apply `key=value` CLI overrides on top.
    pub fn apply_overrides<'a>(&mut self, args: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for a in args {
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override `{a}` is not key=value"))?;
            self.set(k.trim(), v.trim());
        }
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow::anyhow!("config `{key}`={v}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|e| anyhow::anyhow!("config `{key}`={v}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("config `{key}`={v}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => anyhow::bail!("config `{key}`={v}: expected bool"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_file_content() {
        let c = Config::from_str_content(
            "# comment\nrows = 1000\nbackend = piper-net  # trailing\n\nvocab=5000\n",
        )
        .unwrap();
        assert_eq!(c.get("rows"), Some("1000"));
        assert_eq!(c.get("backend"), Some("piper-net"));
        assert_eq!(c.get_usize("vocab", 0).unwrap(), 5000);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::from_str_content("this is not kv\n").is_err());
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::from_str_content("rows = 10\n").unwrap();
        c.apply_overrides(["rows=99", "extra=1"]).unwrap();
        assert_eq!(c.get_usize("rows", 0).unwrap(), 99);
        assert_eq!(c.get("extra"), Some("1"));
        assert!(c.apply_overrides(["bad-override"]).is_err());
    }

    #[test]
    fn typed_accessors() {
        let c = Config::from_str_content(
            "n = 1_000_000\nf = 2.5\nt = true\nf2 = no\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("n", 0).unwrap(), 1_000_000);
        assert_eq!(c.get_f64("f", 0.0).unwrap(), 2.5);
        assert!(c.get_bool("t", false).unwrap());
        assert!(!c.get_bool("f2", true).unwrap());
        assert_eq!(c.get_usize("missing", 7).unwrap(), 7);
        assert!(c.get_usize("f", 0).is_err());
    }
}
