//! Table/figure renderers for the bench harness — ASCII tables with the
//! same rows/series the paper reports, every time cell tagged as
//! `measured` (wallclock on this machine) or `sim` (model output).

use std::fmt::Write as _;
use std::time::Duration;

/// Provenance of a reported time — never mixed silently (DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeTag {
    /// Really elapsed on this machine.
    Measured,
    /// Output of a calibrated model (FPGA cycles, GPU model, disk model).
    Sim,
    /// Sum of measured and simulated components.
    Mixed,
}

impl TimeTag {
    pub fn suffix(&self) -> &'static str {
        match self {
            TimeTag::Measured => "meas",
            TimeTag::Sim => "sim",
            TimeTag::Mixed => "meas+sim",
        }
    }
}

/// Throughput in rows/second over a duration — the one shared definition
/// every result type uses (guards against zero durations).
pub fn rows_per_sec(rows: usize, d: Duration) -> f64 {
    rows as f64 / d.as_secs_f64().max(1e-12)
}

/// Format a duration compactly (µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 100.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.0}s", s)
    }
}

/// Format a tagged duration, e.g. `1.25s[sim]`.
pub fn fmt_tagged(d: Duration, tag: TimeTag) -> String {
    format!("{}[{}]", fmt_duration(d), tag.suffix())
}

/// Format a throughput in rows/s with scientific mantissa like the
/// paper's Table 3 (e.g. `1.56E+6`).
pub fn fmt_rows_per_sec(v: f64) -> String {
    if v <= 0.0 {
        return "-".to_string();
    }
    let exp = v.log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{mant:.2}E+{exp}")
}

/// Format a speedup factor like the paper (`4.7×`).
pub fn fmt_speedup(v: f64) -> String {
    format!("{v:.1}×")
}

/// A renderable ASCII table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn note(&mut self, n: &str) -> &mut Self {
        self.notes.push(n.to_string());
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line_len: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let sep = "-".repeat(line_len);
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        let _ = writeln!(out, "{sep}");
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_per_sec_is_total_rows_over_seconds() {
        assert_eq!(rows_per_sec(1000, Duration::from_secs(2)), 500.0);
        // zero duration must not divide by zero
        assert!(rows_per_sec(10, Duration::ZERO).is_finite());
        assert_eq!(rows_per_sec(0, Duration::from_secs(1)), 0.0);
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_micros(5)), "5.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(3)), "3.00s");
        assert_eq!(fmt_duration(Duration::from_secs(250)), "250s");
    }

    #[test]
    fn rows_per_sec_matches_paper_style() {
        assert_eq!(fmt_rows_per_sec(1.56e6), "1.56E+6");
        assert_eq!(fmt_rows_per_sec(975_000.0), "9.75E+5");
        assert_eq!(fmt_rows_per_sec(0.0), "-");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "column"]);
        t.row(&["1".into(), "x".into()]);
        t.row(&["1234".into(), "y".into()]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| 1234 | y"));
        assert!(s.contains("note: hello"));
    }

    #[test]
    #[should_panic]
    fn row_width_enforced() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn tags_are_explicit() {
        let s = fmt_tagged(Duration::from_secs(1), TimeTag::Sim);
        assert!(s.ends_with("[sim]"));
        assert!(fmt_tagged(Duration::from_secs(1), TimeTag::Measured).ends_with("[meas]"));
    }
}
