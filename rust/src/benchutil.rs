//! Support for the bench harness (`rust/benches/*`): workload sizing,
//! paper reference values, and scaling helpers shared by the
//! table/figure regenerators.

use crate::data::{synth::SynthConfig, SynthDataset};

/// Paper-scale constants (Criteo Kaggle, §4.1).
pub mod paper {
    /// Rows in the Criteo Kaggle dataset (≈46M; 11 GB / ~240 B per row).
    pub const ROWS: usize = 46_000_000;
    /// Raw UTF-8 size in bytes.
    pub const UTF8_BYTES: usize = 11_000_000_000;
    /// Decoded binary size in bytes.
    pub const BINARY_BYTES: usize = 8_200_000_000;
}

/// Bench workload row count: `PIPER_BENCH_ROWS` env var, else `default`.
pub fn bench_rows(default: usize) -> usize {
    std::env::var("PIPER_BENCH_ROWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Repetitions for measured numbers: `PIPER_BENCH_REPS`, else `default`.
pub fn bench_reps(default: usize) -> usize {
    std::env::var("PIPER_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The standard bench dataset.
pub fn dataset(rows: usize) -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(rows))
}

/// Scale a measured per-`n`-rows duration to the paper's 46M rows —
/// legitimate because every pipeline stage is streaming (DESIGN.md §4
/// scale note). Clearly a projection; callers label it.
pub fn scale_to_paper_rows(measured: std::time::Duration, rows: usize) -> std::time::Duration {
    measured.mul_f64(paper::ROWS as f64 / rows.max(1) as f64)
}

/// Median of a set of measured durations.
pub fn median(mut xs: Vec<std::time::Duration>) -> std::time::Duration {
    xs.sort();
    xs[xs.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn scaling_is_linear() {
        let d = scale_to_paper_rows(Duration::from_secs(1), paper::ROWS / 2);
        assert_eq!(d, Duration::from_secs(2));
    }

    #[test]
    fn median_is_middle() {
        let m = median(vec![
            Duration::from_secs(9),
            Duration::from_secs(1),
            Duration::from_secs(5),
        ]);
        assert_eq!(m, Duration::from_secs(5));
    }

    #[test]
    fn env_overrides_parse() {
        // no env set in tests → defaults
        assert_eq!(bench_rows(123), 123);
        assert_eq!(bench_reps(3), 3);
    }
}
