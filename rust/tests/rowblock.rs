//! Acceptance tests for the columnar data plane:
//!
//! * property-style `RowBlock ↔ DecodedRow` round trips over random
//!   schemas and row counts;
//! * `partition_rows` range-slicing of a block matches the unsliced
//!   result at every boundary;
//! * the engine's allocation-recycling loops: a two-pass (`gen_vocab`)
//!   run over a `SynthSource` reuses pooled raw buffers instead of
//!   allocating per chunk, and `RunReport::decode_passes` pins the
//!   rewind count.

use piper::accel::InputFormat;
use piper::coordinator::Backend;
use piper::cpu_baseline::{pipeline::partition_rows, ConfigKind};
use piper::data::row::ProcessedColumns;
use piper::data::{RowBlock, SynthConfig, SynthDataset};
use piper::ops::PipelineSpec;
use piper::pipeline::{CountSink, PipelineBuilder, Source, SynthSource};
use piper::util::XorShift64;

#[test]
fn property_rowblock_roundtrip_random_schemas() {
    let mut rng = XorShift64::new(0xB10C);
    for case in 0..40 {
        let schema = piper::data::Schema::new(
            1 + rng.below(8) as usize,
            1 + rng.below(12) as usize,
        );
        let mut cfg = SynthConfig::small(1 + rng.below(200) as usize);
        cfg.schema = schema;
        cfg.seed = rng.next_u64();
        let ds = SynthDataset::generate(cfg);

        let block = RowBlock::from_rows(&ds.rows, schema);
        assert_eq!(block.num_rows(), ds.rows.len(), "case {case}");
        assert_eq!(block.to_rows(), ds.rows, "case {case} schema {schema:?}");
        for (r, row) in ds.rows.iter().enumerate() {
            assert_eq!(&block.row(r), row, "case {case} row {r}");
        }
        // Column slices agree with the row view.
        for c in 0..schema.num_sparse {
            let col: Vec<u32> = ds.rows.iter().map(|r| r.sparse[c]).collect();
            assert_eq!(block.sparse_col(c), &col[..], "case {case} sparse col {c}");
        }
        for c in 0..schema.num_dense {
            let col: Vec<i32> = ds.rows.iter().map(|r| r.dense[c]).collect();
            assert_eq!(block.dense_col(c), &col[..], "case {case} dense col {c}");
        }
    }
}

#[test]
fn property_binary_append_roundtrip_random_cuts() {
    let mut rng = XorShift64::new(0xA99E);
    for case in 0..20 {
        let mut cfg = SynthConfig::small(1 + rng.below(120) as usize);
        cfg.seed = rng.next_u64();
        let ds = SynthDataset::generate(cfg);
        let raw = piper::data::binary::encode_dataset(&ds);
        let rb = ds.schema().binary_row_bytes();

        // Append in random row-aligned pieces; contents must round-trip.
        let mut block = RowBlock::new(ds.schema());
        let mut at = 0;
        while at < raw.len() {
            let rows_left = (raw.len() - at) / rb;
            let take = (1 + rng.below(rows_left as u64)) as usize * rb;
            block.append_binary(&raw[at..at + take]);
            at += take;
        }
        assert_eq!(block.to_rows(), ds.rows, "case {case}");
    }
}

/// Range-slicing a block at every `partition_rows` boundary and gluing
/// the shard outputs must equal processing the unsliced block — the
/// invariant the CPU executor's threading relies on.
#[test]
fn partition_boundaries_match_unsliced_process() {
    let ds = SynthDataset::generate(SynthConfig::small(257)); // prime row count
    let block = RowBlock::from_rows(&ds.rows, ds.schema());
    let plan = piper::pipeline::Plan::compile(
        PipelineSpec::dlrm(97),
        ds.schema(),
        InputFormat::Utf8,
        4096,
    )
    .unwrap();
    let mut state = piper::pipeline::ChunkState::new(&plan);
    state.observe(&block);
    let whole = state.process(&block);

    for threads in [1usize, 2, 3, 5, 8, 13, 256, 257, 300] {
        let parts = partition_rows(block.num_rows(), threads);
        // partition_rows covers the rows exactly, in order.
        assert_eq!(parts.first().map(|r| r.start), Some(0));
        assert_eq!(parts.iter().map(|r| r.len()).sum::<usize>(), block.num_rows());
        let mut glued = ProcessedColumns::with_schema(ds.schema());
        for range in parts {
            glued.extend_from(&state.process_range(&block, range));
        }
        assert_eq!(glued, whole, "threads={threads}");
    }
}

/// Source wrapper that counts how many times the engine handed it a
/// fresh (zero-capacity) buffer vs a recycled one.
struct PoolMeter {
    inner: SynthSource,
    fresh: usize,
    calls: usize,
}

impl Source for PoolMeter {
    fn format(&self) -> InputFormat {
        self.inner.format()
    }
    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> piper::Result<bool> {
        self.calls += 1;
        if buf.capacity() == 0 {
            self.fresh += 1;
        }
        self.inner.next_chunk(max_bytes, buf)
    }
    fn can_rewind(&self) -> bool {
        self.inner.can_rewind()
    }
    fn reset(&mut self) -> piper::Result<()> {
        self.inner.reset()
    }
}

/// Regression pin for the two-pass decode waste: the second (rewound)
/// pass must reuse the pooled raw buffers of the first, so fresh
/// allocations stay bounded by the channel depth — not by the chunk
/// count — and resident memory does not grow with the dataset.
#[test]
fn second_pass_reuses_pooled_buffers() {
    let rows = 4_000usize;
    let depth = 2usize;
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(997))
        .input(InputFormat::Utf8)
        .chunk_rows(64) // many chunks per pass
        .channel_depth(depth)
        .strategy(piper::pipeline::ExecStrategy::TwoPass) // the rewind under test
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .build()
        .unwrap();

    let mut src = PoolMeter {
        inner: SynthSource::new(SynthConfig::small(rows), InputFormat::Utf8),
        fresh: 0,
        calls: 0,
    };
    let mut sink = CountSink::new();
    let report = pipeline.run(&mut src, &mut sink).unwrap();

    assert_eq!(report.decode_passes, 2, "gen_vocab plan must rewind once");
    assert_eq!(sink.rows, rows);
    assert!(src.calls > 40, "test needs many chunks, got {}", src.calls);
    // At most depth + 2 buffers are in flight at once (producer + queue
    // + consumer); everything else — including all of pass 2 after the
    // rewind — must come from the pool. A small slack absorbs transient
    // send/try_recv races; the point is O(depth), not O(chunks).
    assert!(
        src.fresh <= depth + 4,
        "pass 2 leaked allocations: {} fresh buffers over {} chunks",
        src.fresh,
        src.calls
    );
}

/// Non-vocab plans stream in a single pass.
#[test]
fn single_pass_plans_report_one_decode_pass() {
    let pipeline = PipelineBuilder::new()
        .spec_str("modulus:97|logarithm")
        .unwrap()
        .input(InputFormat::Utf8)
        .chunk_rows(256)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .build()
        .unwrap();
    let mut src = SynthSource::new(SynthConfig::small(500), InputFormat::Utf8);
    let mut sink = CountSink::new();
    let report = pipeline.run(&mut src, &mut sink).unwrap();
    assert_eq!(report.decode_passes, 1);
    assert_eq!(sink.rows, 500);
}
