//! Acceptance tests for the Source/Plan/Executor/Sink redesign:
//!
//! * a `Pipeline` built ONCE runs multiple `Source`s (in-memory, file,
//!   synth, TCP) on all executors (CPU baseline, GPU model, PIPER) with
//!   bit-identical `ProcessedColumns` to the pre-redesign one-shot paths
//!   (`cpu_baseline::run`, `gpu_sim::run`, `accel::run`);
//! * capability/config mismatches are planning errors;
//! * resident raw input during a file-sourced run is bounded by the
//!   chunk size, never the dataset.

use piper::accel::{self, InputFormat, Mode, PiperConfig};
use piper::coordinator::Backend;
use piper::cpu_baseline::{self, BaselineConfig, ConfigKind};
use piper::data::row::ProcessedColumns;
use piper::data::{binary, synth::SynthConfig, utf8, SynthDataset};
use piper::gpu_sim::{self, GpuInput, GpuModel};
use piper::ops::{Modulus, PipelineSpec};
use piper::pipeline::{
    serve_bytes, CountSink, ExecStrategy, FileSource, MemorySource, Pipeline, PipelineBuilder,
    Source, SynthSource, TcpSource,
};
use piper::report::TimeTag;

const ROWS: usize = 350;
const VOCAB: u32 = 997;

fn dataset() -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(ROWS))
}

fn build(backend: &Backend, input: InputFormat, chunk_rows: usize) -> Pipeline {
    PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(dataset().schema())
        .input(input)
        .chunk_rows(chunk_rows)
        .executor(backend.executor())
        .build()
        .expect("planning must succeed for a valid config")
}

/// The pre-redesign reference output: the staged CPU baseline run
/// directly over the raw buffer (all legacy backends agreed with it, as
/// their tests still assert).
fn legacy_reference(raw: &[u8]) -> ProcessedColumns {
    cpu_baseline::run(
        &BaselineConfig::new(ConfigKind::I, 3, Modulus::new(VOCAB)),
        raw,
    )
    .processed
}

#[test]
fn one_pipeline_many_sources_many_executors_bit_identical() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let reference = legacy_reference(&raw);

    // Also pin the other legacy one-shot paths to the same reference.
    let gpu_legacy = gpu_sim::run(
        &GpuModel::default(),
        ds.schema(),
        Modulus::new(VOCAB),
        GpuInput::Utf8,
        &raw,
    )
    .unwrap()
    .processed;
    assert_eq!(gpu_legacy, reference);
    let mut piper_cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::new(VOCAB));
    piper_cfg.schema = ds.schema();
    let piper_legacy = accel::run(&piper_cfg, &raw).unwrap().processed;
    assert_eq!(piper_legacy, reference);

    let file = std::env::temp_dir().join(format!("piper-api-{}.txt", std::process::id()));
    std::fs::write(&file, &raw).unwrap();

    for backend in [
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
        Backend::Piper { mode: Mode::LocalDecodeInKernel },
    ] {
        // Built once…
        let pipeline = build(&backend, InputFormat::Utf8, 64);
        // …run over an in-memory source…
        let mut mem = MemorySource::new(&raw, InputFormat::Utf8);
        let (mem_cols, mem_report) = pipeline.run_collect(&mut mem).unwrap();
        assert_eq!(mem_cols, reference, "{} / memory", backend.name());
        assert_eq!(mem_report.rows, ROWS);
        // …a file source…
        let mut fsrc = FileSource::open(&file, InputFormat::Utf8).unwrap();
        let (file_cols, file_report) = pipeline.run_collect(&mut fsrc).unwrap();
        assert_eq!(file_cols, reference, "{} / file", backend.name());
        assert!(file_report.chunks > 1, "small chunks must chunk the file");
        // …and a generator source, all through the SAME pipeline object.
        let mut synth = SynthSource::new(SynthConfig::small(ROWS), InputFormat::Utf8);
        let (synth_cols, _) = pipeline.run_collect(&mut synth).unwrap();
        assert_eq!(synth_cols, reference, "{} / synth", backend.name());
    }
    std::fs::remove_file(&file).ok();
}

#[test]
fn binary_input_is_bit_identical_too() {
    let ds = dataset();
    let raw = binary::encode_dataset(&ds);
    let reference = legacy_reference(&utf8::encode_dataset(&ds));

    for backend in [
        Backend::Cpu { kind: ConfigKind::III, threads: 2 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ] {
        let pipeline = build(&backend, InputFormat::Binary, 128);
        let mut src = MemorySource::new(&raw, InputFormat::Binary);
        let (cols, _) = pipeline.run_collect(&mut src).unwrap();
        assert_eq!(cols, reference, "{} / binary", backend.name());
    }
}

#[test]
fn chunk_size_never_changes_output() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let reference = legacy_reference(&raw);
    for chunk_rows in [1usize, 7, 100, 1_000_000] {
        let pipeline =
            build(&Backend::Cpu { kind: ConfigKind::I, threads: 3 }, InputFormat::Utf8, chunk_rows);
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, _) = pipeline.run_collect(&mut src).unwrap();
        assert_eq!(cols, reference, "chunk_rows={chunk_rows}");
    }
}

#[test]
fn tcp_source_through_the_engine() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let reference = legacy_reference(&raw);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let payload = raw.clone();
    // Fused plan (the default) ⇒ the dataset crosses the wire ONCE.
    let server = std::thread::spawn(move || serve_bytes(&listener, &payload, 1));

    let pipeline = build(&Backend::Piper { mode: Mode::Network }, InputFormat::Utf8, 50);
    assert_eq!(pipeline.plan().strategy, ExecStrategy::Fused);
    let mut src = TcpSource::connect(&addr, InputFormat::Utf8);
    let (cols, report) = pipeline.run_collect(&mut src).unwrap();
    server.join().unwrap().unwrap();
    assert_eq!(cols, reference);
    assert_eq!(report.tag, TimeTag::Sim);
    assert_eq!(report.decode_passes, 1);
}

#[test]
fn tcp_source_two_pass_crosses_the_wire_twice() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let reference = legacy_reference(&raw);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let payload = raw.clone();
    let server = std::thread::spawn(move || serve_bytes(&listener, &payload, 2));

    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(ds.schema())
        .input(InputFormat::Utf8)
        .chunk_rows(50)
        .strategy(ExecStrategy::TwoPass)
        .executor(Backend::Piper { mode: Mode::Network }.executor())
        .build()
        .unwrap();
    let mut src = TcpSource::connect(&addr, InputFormat::Utf8);
    let (cols, report) = pipeline.run_collect(&mut src).unwrap();
    server.join().unwrap().unwrap();
    assert_eq!(cols, reference);
    assert_eq!(report.decode_passes, 2);
}

/// Source wrapper that records the largest chunk the engine ever asked
/// it to hold — the boundedness proof for file-sourced runs.
struct MeteredSource<S: Source> {
    inner: S,
    max_chunk: usize,
    total: u64,
}

impl<S: Source> Source for MeteredSource<S> {
    fn format(&self) -> InputFormat {
        self.inner.format()
    }
    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> piper::Result<bool> {
        let got = self.inner.next_chunk(max_bytes, buf)?;
        if got {
            self.max_chunk = self.max_chunk.max(buf.len());
            self.total += buf.len() as u64;
        }
        Ok(got)
    }
    fn can_rewind(&self) -> bool {
        self.inner.can_rewind()
    }
    fn reset(&mut self) -> piper::Result<()> {
        self.inner.reset()
    }
}

#[test]
fn file_run_memory_is_bounded_by_chunk_rows_not_dataset() {
    let ds = SynthDataset::generate(SynthConfig::small(2_000));
    let raw = utf8::encode_dataset(&ds);
    let file = std::env::temp_dir().join(format!("piper-bound-{}.txt", std::process::id()));
    std::fs::write(&file, &raw).unwrap();

    let chunk_rows = 100;
    for strategy in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(VOCAB))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(chunk_rows)
            .strategy(strategy)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
            .build()
            .unwrap();
        let chunk_bytes = pipeline.plan().chunk_bytes();
        assert!(
            (chunk_bytes as u64) < raw.len() as u64 / 4,
            "test needs chunks much smaller than the dataset"
        );

        let mut src = MeteredSource {
            inner: FileSource::open(&file, InputFormat::Utf8).unwrap(),
            max_chunk: 0,
            total: 0,
        };
        let mut sink = CountSink::new();
        let report = pipeline.run(&mut src, &mut sink).unwrap();

        assert_eq!(sink.rows, 2_000);
        // Raw input is only ever materialized in ≤ chunk_bytes pieces;
        // the engine keeps at most a few of them in flight at once.
        assert!(src.max_chunk <= chunk_bytes, "{} > {chunk_bytes}", src.max_chunk);
        // The decode-pass count is exactly what crossed the file.
        let passes = match strategy {
            ExecStrategy::Fused => 1,
            ExecStrategy::TwoPass => 2,
        };
        assert_eq!(src.total, passes * raw.len() as u64, "{strategy:?}");
        assert_eq!(report.decode_passes, passes as usize);
        assert!(report.chunks >= raw.len() / chunk_bytes, "chunked, not slurped");
    }
    std::fs::remove_file(&file).ok();
}

#[test]
fn planning_errors_surface_at_build_not_run() {
    // Config III is binary-only (paper Table 2): planning must refuse.
    let err = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .input(InputFormat::Utf8)
        .executor(Backend::Cpu { kind: ConfigKind::III, threads: 2 }.executor())
        .build();
    assert!(err.is_err(), "Config III must not plan over UTF-8");
    let msg = format!("{:#}", err.err().expect("checked above"));
    assert!(msg.contains("planning"), "error should read as a planning error: {msg}");

    // Mismatched source format is rejected before any work happens.
    let pipeline = build(&Backend::Gpu, InputFormat::Binary, 64);
    let raw = utf8::encode_dataset(&dataset());
    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
    assert!(pipeline.run_collect(&mut src).is_err());
}

#[test]
fn reused_pipeline_is_deterministic_across_submissions() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let pipeline = build(&Backend::Piper { mode: Mode::Network }, InputFormat::Utf8, 64);
    let mut first = None;
    for _ in 0..3 {
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, report) = pipeline.run_collect(&mut src).unwrap();
        assert!(report.vocab_entries > 0);
        let expect = first.get_or_insert_with(|| cols.clone());
        assert_eq!(expect, &cols, "resubmission must not mutate the pipeline");
    }
}
