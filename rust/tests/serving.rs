//! Serving-mode suite over real loopback TCP: bit-equivalence of the
//! online request path against the batch two-pass ApplyVocab path
//! (across wire formats × miss policies), admission control, and the
//! worker's error posture against malformed streams.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};

use piper::data::{binary, utf8, RowBlock, Schema, SynthConfig, SynthDataset};
use piper::net::{self, protocol, stream::WireFormat, ServeJob, ServeStatus};
use piper::ops::{PipelineSpec, VocabArtifact};
use piper::pipeline::{ChunkDecoder, ChunkState, FrozenPlan, MissPolicy};

/// One GenVocab pass over a dataset, frozen into an artifact.
fn freeze_from(ds: &SynthDataset, spec: &PipelineSpec) -> VocabArtifact {
    let schema = ds.schema();
    let mut state = ChunkState::with_programs(spec.compile(schema).expect("spec compiles"));
    let raw = binary::encode_dataset(ds);
    let mut block = RowBlock::new(schema);
    let mut dec = ChunkDecoder::new(piper::accel::InputFormat::Binary, schema);
    dec.feed_into(&raw, &mut block).expect("decode");
    dec.finish_into(&mut block).expect("decode end");
    state.observe(&block);
    let vocabs = state.vocabs.iter().map(|v| v.export_keys()).collect();
    VocabArtifact::new(spec.clone(), schema, vocabs).expect("artifact")
}

/// Cut an encoded dataset into request payloads of ~`rows_per_req`
/// rows, honoring each format's row framing.
fn split_requests(
    raw: &[u8],
    format: WireFormat,
    schema: Schema,
    rows_per_req: usize,
) -> Vec<Vec<u8>> {
    match format {
        WireFormat::Binary => raw
            .chunks(schema.binary_row_bytes() * rows_per_req)
            .map(<[u8]>::to_vec)
            .collect(),
        WireFormat::Utf8 => {
            let mut out = Vec::new();
            let (mut start, mut count) = (0usize, 0usize);
            for (i, &b) in raw.iter().enumerate() {
                if b == b'\n' {
                    count += 1;
                    if count == rows_per_req {
                        out.push(raw[start..=i].to_vec());
                        start = i + 1;
                        count = 0;
                    }
                }
            }
            if start < raw.len() {
                out.push(raw[start..].to_vec());
            }
            out
        }
    }
}

fn spawn_worker() -> (String, std::thread::JoinHandle<piper::Result<protocol::RunStats>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    (addr, std::thread::spawn(move || net::serve_one(&listener)))
}

/// The tentpole equivalence: for every wire format and every miss
/// policy, rows served over TCP are bit-identical to the local frozen
/// apply — and under the sentinel policy, to the *batch two-pass*
/// ApplyVocab path itself (vocabularies imported, pass 2 only).
#[test]
fn served_rows_match_the_batch_apply_path() {
    let spec = PipelineSpec::dlrm(5000);
    let train = SynthDataset::generate(SynthConfig::small(1500));
    let artifact = freeze_from(&train, &spec);
    let schema = train.schema();
    // Request traffic from a different seed — it must contain keys the
    // frozen vocabulary has never seen, or the policies are untested.
    let mut qcfg = SynthConfig::small(240);
    qcfg.seed ^= 0x5eed;
    let queries = SynthDataset::generate(qcfg);

    for format in [WireFormat::Utf8, WireFormat::Binary] {
        let raw = match format {
            WireFormat::Utf8 => utf8::encode_dataset(&queries),
            WireFormat::Binary => binary::encode_dataset(&queries),
        };
        let payloads = split_requests(&raw, format, schema, 50);
        assert!(payloads.len() >= 4, "enough requests to be interesting");

        for policy in [MissPolicy::Sentinel, MissPolicy::DefaultIndex(0), MissPolicy::RejectRow]
        {
            let (addr, server) = spawn_worker();
            let job = ServeJob {
                policy,
                format,
                queue_depth: 8,
                artifact: artifact.clone(),
            };
            let mut client = net::ServeClient::connect(&addr, &job).expect("connect");
            let frozen = FrozenPlan::from_artifact(&artifact, policy).expect("freeze");
            let mut total_misses = 0u64;

            for payload in &payloads {
                let resp = client.request(payload).expect("request");

                // Local reference: same bytes through the frozen plan.
                let mut block = RowBlock::new(schema);
                let mut dec = ChunkDecoder::new(format.into(), schema);
                dec.feed_into(payload, &mut block).expect("local decode");
                dec.finish_into(&mut block).expect("local decode end");
                let local = frozen.apply_block(&block);
                assert_eq!(
                    resp.payload,
                    protocol::pack_columns(&local.columns, schema),
                    "{format:?}/{policy:?}: served bytes != local frozen apply"
                );
                assert_eq!(u64::from(resp.misses), local.misses);
                assert_eq!(u64::from(resp.rejected_rows), local.rejected_rows);
                let want = if local.rejected_rows > 0 {
                    ServeStatus::RejectedRows
                } else {
                    ServeStatus::Ok
                };
                assert_eq!(resp.status, want);
                total_misses += local.misses;

                // Batch reference: under the sentinel policy the served
                // bytes must equal the batch two-pass ApplyVocab output
                // (empty pass 1, imported vocabularies, pass 2 only).
                if policy == MissPolicy::Sentinel {
                    let mut sp = net::StreamingPreprocessor::new(&spec, schema, format)
                        .expect("streaming preprocessor");
                    sp.pass1_end().expect("empty pass 1");
                    sp.import_vocabs(artifact.vocabs().to_vec()).expect("import");
                    let mut rows = sp.pass2_chunk(payload).expect("pass 2");
                    rows.extend(sp.pass2_end().expect("pass 2 end"));
                    assert_eq!(
                        resp.payload,
                        protocol::pack_rows(&rows, schema),
                        "{format:?}: served bytes != batch two-pass ApplyVocab"
                    );
                }
            }

            let (report, late) = client.finish().expect("finish");
            assert!(late.is_empty(), "all responses consumed in-loop");
            assert_eq!(report.requests, payloads.len() as u64);
            assert_eq!(report.misses, total_misses);
            assert!(report.p99_us >= report.p50_us);
            if policy == MissPolicy::Sentinel {
                assert!(total_misses > 0, "query seed must exercise vocabulary misses");
            }
            let stats = server.join().expect("worker thread").expect("worker session");
            assert_eq!(stats.rows, report.rows);
        }
    }
}

/// Admission control: with `queue_depth=1`, a burst behind one large
/// request gets explicit OVERLOADED replies — and every request is
/// still answered exactly once, in arrival order.
#[test]
fn overload_burst_gets_explicit_refusals() {
    let spec = PipelineSpec::dlrm(5000);
    let train = SynthDataset::generate(SynthConfig::small(20_000));
    let artifact = freeze_from(&train, &spec);
    let schema = train.schema();
    let raw = binary::encode_dataset(&train);

    let (addr, server) = spawn_worker();
    let job = ServeJob {
        policy: MissPolicy::Sentinel,
        format: WireFormat::Binary,
        queue_depth: 1,
        artifact,
    };
    let mut client = net::ServeClient::connect(&addr, &job).expect("connect");

    // One large request holds the single processing slot...
    client.send(&raw).expect("big request");
    // ...while a burst of tiny ones races into admission.
    let n_small = 16usize;
    for _ in 0..n_small {
        client.send(&raw[..schema.binary_row_bytes()]).expect("small request");
    }
    let mut responses = Vec::with_capacity(n_small + 1);
    for _ in 0..n_small + 1 {
        responses.push(client.recv().expect("response"));
    }
    let (report, late) = client.finish().expect("finish");
    assert!(late.is_empty());

    let overloaded =
        responses.iter().filter(|r| r.status == ServeStatus::Overloaded).count();
    assert!(overloaded >= 1, "queue_depth=1 burst must refuse at least one request");
    assert_eq!(report.overloaded, overloaded as u64);
    assert_eq!(report.requests, (n_small + 1) as u64);
    assert!(report.p50_us > 0, "latency window recorded the served requests");
    // Exactly-once, id-echoed answers.
    let mut ids: Vec<u64> = responses.iter().map(|r| r.req_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..=n_small as u64).collect::<Vec<_>>());
    // Refused requests carry no rows.
    for r in &responses {
        if r.status == ServeStatus::Overloaded {
            assert!(r.payload.is_empty());
        }
    }
    server.join().expect("worker thread").expect("worker session");
}

/// A malformed request gets a BAD_REQUEST reply and the session keeps
/// serving — one bad client batch must not tear down the connection.
#[test]
fn bad_request_does_not_end_the_tcp_session() {
    let spec = PipelineSpec::dlrm(5000);
    let train = SynthDataset::generate(SynthConfig::small(500));
    let artifact = freeze_from(&train, &spec);
    let schema = train.schema();
    let raw = binary::encode_dataset(&train);

    let (addr, server) = spawn_worker();
    let job = ServeJob {
        policy: MissPolicy::Sentinel,
        format: WireFormat::Binary,
        queue_depth: 4,
        artifact,
    };
    let mut client = net::ServeClient::connect(&addr, &job).expect("connect");

    let misaligned = &raw[..schema.binary_row_bytes() + 3];
    let bad = client.request(misaligned).expect("bad request still gets a reply");
    assert_eq!(bad.status, ServeStatus::BadRequest);
    assert!(!bad.payload.is_empty(), "the reason travels in the payload");

    let good = client.request(&raw[..schema.binary_row_bytes()]).expect("served after");
    assert_eq!(good.status, ServeStatus::Ok);
    assert_eq!(good.rows(schema), 1);

    let (report, _) = client.finish().expect("finish");
    assert_eq!((report.bad_requests, report.ok), (1, 1));
    server.join().expect("worker thread").expect("worker session");
}

/// A garbage job header gets an ERROR reply with the reason, then a
/// clean close — never a panic, never a silent hang.
#[test]
fn hostile_job_header_gets_an_error_reply() {
    let (addr, server) = spawn_worker();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    protocol::write_frame(&mut stream, protocol::Tag::ServeJob, &[1, 2, 3]).expect("write");
    stream.flush().expect("flush");

    let (tag, payload) = protocol::read_frame(&mut stream).expect("error frame");
    assert_eq!(tag, protocol::Tag::ErrorReply);
    assert!(!payload.is_empty(), "the reply must say what was wrong");
    // The worker closed after replying.
    use std::io::Read as _;
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).expect("clean close");
    assert!(rest.is_empty());
    assert!(server.join().expect("worker thread").is_err());
}

/// A truncated frame (peer hangs up mid-header) fails the session
/// cleanly on the worker side.
#[test]
fn truncated_frame_fails_cleanly() {
    let (addr, server) = spawn_worker();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    stream.write_all(&[protocol::Tag::Job as u8, 9, 9]).expect("partial header");
    drop(stream);
    assert!(server.join().expect("worker thread").is_err(), "error, not a hang or panic");
}

/// A frame header claiming an absurd length is refused before any
/// allocation — the worker replies with the error and closes.
#[test]
fn oversized_frame_is_refused_up_front() {
    let (addr, server) = spawn_worker();
    let mut stream = TcpStream::connect(&addr).expect("connect");
    let mut hdr = vec![protocol::Tag::Job as u8];
    hdr.extend_from_slice(&(u64::MAX).to_le_bytes());
    stream.write_all(&hdr).expect("hostile header");
    stream.flush().expect("flush");

    let (tag, payload) = protocol::read_frame(&mut stream).expect("error frame");
    assert_eq!(tag, protocol::Tag::ErrorReply);
    assert!(!payload.is_empty());
    assert!(server.join().expect("worker thread").is_err());
}
