//! Integration: artifacts → PJRT runtime → training loop.
//!
//! Exercises the full rust-side consumer path: load the AOT artifacts,
//! initialize parameters, preprocess a synthetic dataset with PIPER, and
//! take real SGD steps, checking the loss moves. Skipped (cleanly) when
//! `make artifacts` hasn't run. The whole file needs the `pjrt` feature.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use piper::accel::{InputFormat, Mode, PiperConfig};
use piper::data::{synth::SynthConfig, utf8, SynthDataset};
use piper::ops::Modulus;
use piper::runtime::Runtime;
use piper::train::{train_loop, Trainer};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("train_step.hlo.txt").exists().then_some(dir)
}

#[test]
fn artifacts_load_and_train_step_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let mut trainer = Trainer::new(&rt, &dir).unwrap();
    assert_eq!(trainer.meta.num_dense, 13);
    assert_eq!(trainer.meta.num_sparse, 26);

    // Preprocess a small synthetic dataset through the PIPER simulator.
    let rows = trainer.meta.batch * 3;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    let cfg = PiperConfig::paper(
        Mode::Network,
        InputFormat::Utf8,
        Modulus::new(trainer.meta.vocab as u32),
    );
    let run = piper::accel::run(&cfg, &raw).unwrap();
    assert_eq!(run.rows, rows);

    // A few SGD steps: losses must be finite and should decrease on
    // average over the cycling batches.
    let losses = train_loop(&mut trainer, &run.processed, 12).unwrap();
    assert_eq!(losses.len(), 12);
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    let first3: f32 = losses[..3].iter().sum::<f32>() / 3.0;
    let last3: f32 = losses[9..].iter().sum::<f32>() / 3.0;
    assert!(
        last3 < first3,
        "loss should fall: first3={first3:.4} last3={last3:.4} ({losses:?})"
    );
    assert_eq!(trainer.steps_done(), 12);
}

#[test]
fn forward_probabilities_in_range() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let trainer = Trainer::new(&rt, &dir).unwrap();
    let b = trainer.meta.batch;
    let batch = piper::train::Batch {
        dense: vec![0.5; b * trainer.meta.num_dense],
        sparse: vec![1; b * trainer.meta.num_sparse],
        labels: vec![0.0; b],
    };
    let probs = trainer.forward(&batch).unwrap();
    assert_eq!(probs.len(), b);
    assert!(probs.iter().all(|p| (0.0..1.0).contains(p)), "probs out of range");
}
