//! The acceptance suite of the per-column program redesign: a
//! heterogeneous spec (two distinct vocabulary sizes, a vocab-free
//! sparse column, log on only a subset of dense columns, one
//! clipped+bucketized column) must plan and run **bit-identically**
//! across every executor (CPU baseline, GPU model, all three PIPER
//! modes), both execution strategies (fused × two-pass), both input
//! formats (UTF-8 × binary) and several source kinds — and must equal
//! the spec's row-wise reference interpreter
//! ([`piper::ops::PipelineSpec::execute`]).
//!
//! Uniform `[*]` specs are covered by the pre-existing
//! `fused_equivalence` suite, which this PR keeps green unchanged —
//! that is the "uniform specs stay bit-identical to the PR-3
//! baselines" pin.
//!
//! CI runs this suite under `--release` so the per-column dispatch hot
//! loops are exercised optimized.

use piper::accel::{InputFormat, Mode};
use piper::coordinator::Backend;
use piper::cpu_baseline::ConfigKind;
use piper::data::row::ProcessedColumns;
use piper::data::{binary, synth::SynthConfig, utf8, SynthDataset};
use piper::ops::PipelineSpec;
use piper::pipeline::{ExecStrategy, FileSource, MemorySource, Pipeline, PipelineBuilder};

const ROWS: usize = 330;

/// Two vocabulary sizes, one vocab-free sparse column, partial dense
/// log, one clipped+bucketized dense column.
const HETERO_SPEC: &str = "sparse[*]: modulus:997|genvocab|applyvocab; \
                           sparse[0..4]: modulus:5000|genvocab|applyvocab; \
                           sparse[5]: modulus:53; \
                           dense[*]: neg2zero|logarithm; \
                           dense[0..3]: neg2zero; \
                           dense[12]: clip:0:100|bucketize:1:10:100";

fn dataset() -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(ROWS))
}

fn all_backends(input: InputFormat) -> Vec<Backend> {
    let cpu_kind = match input {
        InputFormat::Utf8 => ConfigKind::I,
        InputFormat::Binary => ConfigKind::III,
    };
    vec![
        Backend::Cpu { kind: cpu_kind, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::LocalDecodeInKernel },
        Backend::Piper { mode: Mode::LocalDecodeInHost },
        Backend::Piper { mode: Mode::Network },
    ]
}

fn build(backend: &Backend, input: InputFormat, strategy: ExecStrategy) -> Pipeline {
    PipelineBuilder::new()
        .spec_str(HETERO_SPEC)
        .expect("heterogeneous spec parses")
        .schema(dataset().schema())
        .input(input)
        .chunk_rows(64)
        .strategy(strategy)
        .executor(backend.executor())
        .build()
        .expect("heterogeneous spec must plan on every executor")
}

/// The core guarantee: the heterogeneous per-column spec runs
/// bit-identically across executors × strategies × formats × sources,
/// and equals the spec's reference interpreter.
#[test]
fn heterogeneous_spec_bit_identical_everywhere() {
    let ds = dataset();
    let spec = PipelineSpec::parse(HETERO_SPEC).unwrap();
    let reference = spec.execute(&ds.rows, ds.schema()).unwrap();

    for input in [InputFormat::Utf8, InputFormat::Binary] {
        let raw = match input {
            InputFormat::Utf8 => utf8::encode_dataset(&ds),
            InputFormat::Binary => binary::encode_dataset(&ds),
        };
        let file = std::env::temp_dir().join(format!(
            "piper-program-eq-{}-{input:?}.dat",
            std::process::id()
        ));
        std::fs::write(&file, &raw).unwrap();

        for backend in all_backends(input) {
            for strategy in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
                let pipeline = build(&backend, input, strategy);
                let mut src = MemorySource::new(&raw, input);
                let (cols, report) = pipeline.run_collect(&mut src).unwrap();
                assert_eq!(
                    cols,
                    reference,
                    "{} {input:?} {strategy:?} must equal the reference interpreter",
                    backend.name()
                );
                assert_eq!(report.rows, ROWS);
                assert_eq!(report.strategy, strategy);

                // File source through the same pipeline.
                let mut fsrc = FileSource::open(&file, input).unwrap();
                let (file_cols, _) = pipeline.run_collect(&mut fsrc).unwrap();
                assert_eq!(
                    file_cols,
                    reference,
                    "{} {input:?} {strategy:?} / file",
                    backend.name()
                );
            }
        }
        std::fs::remove_file(&file).ok();
    }
}

/// Per-column vocabulary accounting: only the 25 vocab-building columns
/// contribute entries, the 5000-range columns build bigger
/// vocabularies than the 997-range ones can, and the totals agree
/// across executors.
#[test]
fn heterogeneous_vocab_accounting_agrees() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let mut want: Option<usize> = None;
    for backend in all_backends(InputFormat::Utf8) {
        let pipeline = build(&backend, InputFormat::Utf8, ExecStrategy::Fused);
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (_, report) = pipeline.run_collect(&mut src).unwrap();
        assert!(report.vocab_entries > 0);
        let expect = *want.get_or_insert(report.vocab_entries);
        assert_eq!(report.vocab_entries, expect, "{}", backend.name());
    }
}

/// The uniform DLRM spec expressed as a flat string, as the dlrm()
/// preset, and as its own display form must all plan to the same
/// output — the compatibility pin for old spec strings (the flat
/// grammar is `[*]`-selector sugar).
#[test]
fn uniform_spec_forms_agree() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let flat = "decode|fillmissing|hex2int|modulus:997|genvocab|applyvocab\
                |neg2zero|logarithm|concatenate";
    let preset = PipelineSpec::dlrm(997);
    assert_eq!(PipelineSpec::parse(flat).unwrap(), preset);

    let run = |spec: PipelineSpec| -> ProcessedColumns {
        let pipeline = PipelineBuilder::new()
            .spec(spec)
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(64)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
            .build()
            .unwrap();
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        pipeline.run_collect(&mut src).unwrap().0
    };
    let from_flat = run(PipelineSpec::parse(flat).unwrap());
    let from_preset = run(preset.clone());
    let from_display = run(PipelineSpec::parse(&preset.to_string()).unwrap());
    assert_eq!(from_flat, from_preset);
    assert_eq!(from_display, from_preset);
}

/// An all-SRAM-overflowing program set must fail at planning on the
/// accelerator, while the same vocabulary budget spread across a few
/// columns plans fine — the per-column SRAM sum at work.
#[test]
fn accel_sram_check_sums_per_column_capacities() {
    let ds = dataset();
    // 26 × 1M does not fit the 43 MB SRAM budget…
    let uniform_big = PipelineBuilder::new()
        .spec_str("sparse[*]: modulus:1000000|genvocab|applyvocab")
        .unwrap()
        .schema(ds.schema())
        .input(InputFormat::Utf8)
        .executor(Backend::Piper { mode: Mode::LocalDecodeInKernel }.executor())
        .build();
    // (1M vocab selects the HBM paper build by default, so force SRAM
    // via a 100K+ heterogeneous mix that keeps the default SRAM build.)
    assert!(uniform_big.is_ok(), "paper 1M build plans into HBM placement");

    // …but a handful of big columns among small ones fits SRAM: the
    // sum prices what the programs declare, not columns × max.
    let hetero = PipelineBuilder::new()
        .spec_str(
            "sparse[*]: modulus:5000|genvocab|applyvocab; \
             sparse[0..4]: modulus:100000|genvocab|applyvocab",
        )
        .unwrap()
        .schema(ds.schema())
        .input(InputFormat::Utf8)
        .executor(Backend::Piper { mode: Mode::LocalDecodeInKernel }.executor())
        .build();
    assert!(hetero.is_ok(), "per-column sum must fit SRAM");

    // A uniform 300K plan keeps the SRAM build (max ≤ the 100K paper
    // threshold is what flips to HBM at 1M; 300K stays SRAM per the
    // clock heuristic) and 26 × 300K ≈ 250 Mbit still fits — but
    // 26 × 4M would not: force it and expect a planning error.
    let forced = PipelineBuilder::new()
        .spec_str("sparse[*]: modulus:4000000|genvocab|applyvocab")
        .unwrap()
        .schema(ds.schema())
        .input(InputFormat::Utf8)
        .executor(Box::new(piper::accel::PiperExecutor::with_config({
            let mut cfg = piper::accel::PiperConfig::paper(
                Mode::LocalDecodeInKernel,
                InputFormat::Utf8,
                piper::ops::Modulus::new(4_000_000),
            );
            cfg.vocab_placement = piper::accel::VocabPlacement::Sram;
            cfg
        })))
        .build();
    assert!(forced.is_err(), "26 × 4M bits must overflow a forced-SRAM build");
}
