//! Integration tests for the `piper` launcher binary: spawn the real
//! executable and check the user-facing flows end to end.

use std::path::PathBuf;
use std::process::Command;

fn piper_bin() -> PathBuf {
    // target/<profile>/piper next to the test binary's directory
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("piper");
    p
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(piper_bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn piper");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["gen-data", "preprocess", "compare", "serve", "submit", "train"] {
        assert!(text.contains(cmd), "help must mention {cmd}: {text}");
    }
}

#[test]
fn gen_data_then_preprocess_roundtrip() {
    let dir = std::env::temp_dir().join(format!("piper-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("ds.txt");

    let (ok, text) = run(&[
        "gen-data",
        "rows=500",
        &format!("out={}", data.display()),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wrote 500 rows"), "{text}");

    let (ok, text) = run(&[
        "preprocess",
        &format!("input={}", data.display()),
        "backend=piper-net",
        "vocab=997",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("500"), "row count must appear: {text}");
    assert!(text.contains("[sim]"), "PIPER times must be sim-tagged: {text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn gen_data_presets_and_binary() {
    let dir = std::env::temp_dir().join(format!("piper-cli-b-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("ml.bin");
    let (ok, text) = run(&[
        "gen-data",
        "rows=200",
        "dataset=movielens",
        "format=binary",
        &format!("out={}", data.display()),
    ]);
    assert!(ok, "{text}");
    // movielens preset: 3 dense + 4 sparse + label = 8 words/row
    assert_eq!(std::fs::metadata(&data).unwrap().len(), 200 * 8 * 4);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn preprocess_accepts_per_column_spec() {
    let dir = std::env::temp_dir().join(format!("piper-cli-pc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("ds.txt");
    let (ok, text) = run(&["gen-data", "rows=400", &format!("out={}", data.display())]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "preprocess",
        &format!("input={}", data.display()),
        "backend=cpu",
        "threads=2",
        "spec=sparse[*]: modulus:997|genvocab|applyvocab; \
         sparse[0..4]: modulus:5000|genvocab|applyvocab; \
         dense[*]: neg2zero|log; dense[0]: clip:0:100|bucketize:1:10:100",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("400"), "row count must appear: {text}");

    // a selector that doesn't fit the schema is a planning error
    let (ok, text) = run(&[
        "preprocess",
        &format!("input={}", data.display()),
        "backend=cpu",
        "spec=sparse[40]: modulus:5|genvocab|applyvocab",
    ]);
    assert!(!ok);
    assert!(text.contains("out of range"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bad_arguments_fail_cleanly() {
    let (ok, text) = run(&["preprocess"]); // missing input=
    assert!(!ok);
    assert!(text.contains("input"), "{text}");

    let (ok, text) = run(&["gen-data", "dataset=unknown"]);
    assert!(!ok);
    assert!(text.contains("preset"), "{text}");

    let (ok, _) = run(&["preprocess", "input=/nonexistent-file", "backend=cpu"]);
    assert!(!ok);
}

#[test]
fn compare_prints_all_backends() {
    let (ok, text) = run(&["compare", "rows=2000", "vocab=499"]);
    assert!(ok, "{text}");
    for b in ["CPU", "GPU", "PIPER"] {
        assert!(text.contains(b), "compare must include {b}: {text}");
    }
    assert!(text.contains("speedup"), "{text}");
}
