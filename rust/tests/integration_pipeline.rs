//! Integration: all preprocessing backends over the same dataset must
//! agree functionally, and the paper's qualitative performance ordering
//! must hold in the timing models at paper scale.

use piper::accel::{dataflow, host::HostModel, network, InputFormat, Mode, PiperConfig};
use piper::coordinator::{compare, run_backend, Backend, Experiment};
use piper::cpu_baseline::ConfigKind;
use piper::data::{binary, synth::SynthConfig, utf8, SynthDataset};
use piper::net::{protocol::Job, stream::WireFormat};
use piper::ops::Modulus;

fn dataset(rows: usize) -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(rows))
}

#[test]
fn five_backends_one_answer() {
    let ds = dataset(400);
    let m = Modulus::new(997);
    let raw = utf8::encode_dataset(&ds);
    let exp = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Utf8) };

    let cpu = run_backend(&Backend::Cpu { kind: ConfigKind::I, threads: 5 }, &exp, &raw)
        .unwrap();
    let gpu = run_backend(&Backend::Gpu, &exp, &raw).unwrap();
    let p_net = run_backend(&Backend::Piper { mode: Mode::Network }, &exp, &raw).unwrap();
    let p_loc =
        run_backend(&Backend::Piper { mode: Mode::LocalDecodeInKernel }, &exp, &raw).unwrap();
    // real TCP loopback
    let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
    let tcp = piper::net::leader::run_loopback(&job, &raw, 8 * 1024).unwrap();

    assert_eq!(cpu.processed, gpu.processed);
    assert_eq!(cpu.processed, p_net.processed);
    assert_eq!(cpu.processed, p_loc.processed);
    assert_eq!(cpu.processed, tcp.processed);
}

#[test]
fn binary_pipeline_agrees_with_utf8() {
    let ds = dataset(300);
    let m = Modulus::new(499);
    let exp_u = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Utf8) };
    let exp_b = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Binary) };
    let from_utf8 = run_backend(
        &Backend::Piper { mode: Mode::Network },
        &exp_u,
        &utf8::encode_dataset(&ds),
    )
    .unwrap();
    let from_bin = run_backend(
        &Backend::Cpu { kind: ConfigKind::III, threads: 3 },
        &exp_b,
        &binary::encode_dataset(&ds),
    )
    .unwrap();
    assert_eq!(from_utf8.processed, from_bin.processed);
}

#[test]
fn compare_emits_speedups_for_all_rows() {
    let ds = dataset(250);
    let m = Modulus::new(997);
    let raw = utf8::encode_dataset(&ds);
    let exp = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Utf8) };
    let rows = compare(
        &[
            Backend::Cpu { kind: ConfigKind::II, threads: 4 },
            Backend::Gpu,
            Backend::Piper { mode: Mode::Network },
        ],
        &exp,
        &raw,
    )
    .unwrap();
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert!(r.rows_per_sec > 0.0, "{}", r.backend);
        assert!(r.speedup_vs_ref > 0.0);
    }
}

/// Paper-scale model properties (Fig. 9 shape): binary ≫ UTF-8 for
/// PIPER; 1M vocab slower than 5K; network beats local; decode-in-host
/// kernel faster but e2e slower than decode-in-kernel.
#[test]
fn paper_scale_orderings_hold() {
    let rows = 46_000_000usize;
    let utf8_bytes = 11_000_000_000usize;
    let bin_bytes = rows * 160;
    let uniq_5k = 26 * 5_000;
    let uniq_1m = 26 * 700_000; // not all 1M slots hit

    let t = |mode, input, m: Modulus, bytes, uniq| {
        let cfg = PiperConfig::paper(mode, input, m);
        dataflow::model_timing(&cfg, bytes, rows, uniq).seconds()
    };

    // binary ≫ utf8 (paper: 71.3× vs 5.1× speedups come from this gap)
    let k_utf8 = t(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K, utf8_bytes, uniq_5k);
    let k_bin = t(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K, bin_bytes, uniq_5k);
    assert!(k_utf8.as_secs_f64() / k_bin.as_secs_f64() > 5.0);

    // 1M vocab slower than 5K on binary (HBM + lower clock)
    let k_bin_1m = t(Mode::Network, InputFormat::Binary, Modulus::VOCAB_1M, bin_bytes, uniq_1m);
    assert!(k_bin_1m > k_bin);

    // decode-in-host: kernel time drops, e2e rises (paper §4.4.3)
    let hm = HostModel::default();
    let cfg_k = PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Utf8, Modulus::VOCAB_5K);
    let cfg_h = PiperConfig::paper(Mode::LocalDecodeInHost, InputFormat::Utf8, Modulus::VOCAB_5K);
    let kk = dataflow::model_timing(&cfg_k, utf8_bytes, rows, uniq_5k).seconds();
    let kh = dataflow::model_timing(&cfg_h, utf8_bytes, rows, uniq_5k).seconds();
    assert!(kh < kk, "host decode must shrink kernel time");
    let e2e_k = hm.local_breakdown(&cfg_k, utf8_bytes, rows, kk).total();
    let e2e_h = hm.local_breakdown(&cfg_h, utf8_bytes, rows, kh).total();
    assert!(e2e_h > e2e_k, "but host decode must lose end-to-end");

    // network beats local e2e
    let e2e_net = network::stream_time(
        &PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K),
        utf8_bytes,
        t(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K, utf8_bytes, uniq_5k),
    );
    assert!(e2e_net < e2e_k);
}

/// The paper's headline: PIPER(net, binary, 5K) vs best-CPU ≈ 71×; we
/// require the model to land in the right decade against the paper's own
/// CPU numbers (Table 3 best Config III: 5.09e5 rows/s).
#[test]
fn headline_speedup_band() {
    let rows = 46_000_000usize;
    let bin_bytes = rows * 160;
    let cfg = PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_5K);
    let kernel = dataflow::model_timing(&cfg, bin_bytes, rows, 26 * 5000);
    let piper_rps = rows as f64 / kernel.seconds().as_secs_f64();
    let paper_cpu_best = 5.09e5; // Table 3, Config III, 64 threads
    let speedup = piper_rps / paper_cpu_best;
    assert!(
        (20.0..120.0).contains(&speedup),
        "modeled binary-5K speedup {speedup:.1}× should be within the paper's decade (46.4×)"
    );
}
