//! Chaos suite: the fault-tolerance contract over real loopback TCP.
//!
//! Every test drives the *production* session code — the harness only
//! interposes a deterministic [`FaultPlan`] between a worker's socket
//! and its session loop (`worker::handle_connection` is generic over
//! the reader/writer pair for exactly this purpose). The contract being
//! pinned, for every fault class:
//!
//! * the cluster run either completes **bit-identical** to the
//!   fault-free run (split-level retry recovered the shard), or
//! * fails with a **typed** [`NetError`] before the job deadline —
//!   never a hang, never a silent wrong answer.
//!
//! Each test bounds every socket with a short I/O deadline and (where a
//! failure is expected) a job deadline, so a regression that introduces
//! a hang fails the suite by timeout instead of wedging CI.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use piper::data::row::ProcessedColumns;
use piper::data::{utf8, Schema, SynthConfig, SynthDataset};
use piper::net::cluster::run_cluster_loopback_cfg;
use piper::net::fault::{FaultKind, FaultPlan};
use piper::net::protocol::Job;
use piper::net::stream::WireFormat;
use piper::net::worker::{self, WorkerOptions};
use piper::net::{run_cluster_cfg, NetConfig, NetError, ServeClient, ServeJob};
use piper::ops::{PipelineSpec, VocabArtifact};
use piper::pipeline::MissPolicy;

const CHUNK: usize = 256;

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

/// Fast-failing knobs: a test must never sit out a 30 s default
/// deadline — every blocking step is bounded in hundreds of ms.
fn chaos_cfg() -> NetConfig {
    NetConfig {
        io_timeout: Some(ms(2000)),
        job_deadline: Some(Duration::from_secs(30)),
        retries: 2,
        backoff: ms(10),
        backoff_cap: ms(100),
        leader_window: 1,
    }
}

fn worker_opts() -> WorkerOptions {
    WorkerOptions { io_timeout: Some(ms(2000)), serve_idle_timeout: None }
}

/// A worker process stand-in: accepts connections concurrently (the
/// cluster parks pass-1 sessions open while retries of *other* shards
/// arrive) and runs the production session loop behind a per-session
/// [`FaultPlan`] — session `i` gets `plans[i]`, later sessions run
/// clean. This is the "one flaky node" model: the plan scripts *which*
/// session misbehaves and *how*, deterministically.
struct ChaosWorker {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl ChaosWorker {
    fn spawn(plans: Vec<FaultPlan>) -> ChaosWorker {
        let opts = worker_opts();
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            let mut session = 0usize;
            let mut inflight = Vec::new();
            loop {
                let Ok((stream, _)) = listener.accept() else { break };
                if stop2.load(Ordering::Acquire) {
                    break; // the poison pill
                }
                let plan = plans.get(session).cloned().unwrap_or_default();
                session += 1;
                inflight.push(std::thread::spawn(move || {
                    let _ = serve_faulty(stream, &plan, &opts);
                }));
            }
            for t in inflight {
                let _ = t.join();
            }
        });
        ChaosWorker { addr, stop, thread }
    }

    fn stop(self) {
        self.stop.store(true, Ordering::Release);
        if let Ok(sock) = self.addr.parse() {
            let _ = TcpStream::connect_timeout(&sock, Duration::from_secs(1));
        }
        let _ = self.thread.join();
    }
}

/// One session: real socket, real session loop, fault plan in between.
fn serve_faulty(stream: TcpStream, plan: &FaultPlan, opts: &WorkerOptions) -> piper::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(opts.io_timeout)?;
    stream.set_write_timeout(opts.io_timeout)?;
    let reader = BufReader::with_capacity(1 << 16, stream.try_clone()?);
    let writer = BufWriter::with_capacity(1 << 16, stream.try_clone()?);
    let (mut fr, mut fw, _hooks) = plan.wrap(reader, writer);
    worker::handle_connection(&mut fr, &mut fw, opts, Some(&stream)).map(|_| ())
}

struct Fixture {
    job: Job,
    raw: Vec<u8>,
    want: ProcessedColumns,
    rows: u64,
}

fn fixture(rows: usize) -> Fixture {
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let spec = PipelineSpec::parse(
        "sparse[*]: modulus:997|genvocab|applyvocab; dense[*]: neg2zero|log",
    )
    .expect("spec parses");
    let want = spec.execute(&ds.rows, ds.schema()).expect("sequential reference");
    let raw = utf8::encode_dataset(&ds);
    let job =
        Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
    Fixture { job, raw, want, rows: ds.rows.len() as u64 }
}

/// Run against chaos workers where worker 0's first session follows
/// `plan` and everything else is clean. Shard 0's first attempt always
/// lands on worker 0, so `plan` scripts exactly one shard attempt.
fn run_with_fault_on_first_session(
    fx: &Fixture,
    workers: usize,
    plan: FaultPlan,
    cfg: &NetConfig,
) -> piper::Result<piper::net::cluster::ClusterRun> {
    let mut pool = vec![ChaosWorker::spawn(vec![plan])];
    for _ in 1..workers {
        pool.push(ChaosWorker::spawn(Vec::new()));
    }
    let addrs: Vec<String> = pool.iter().map(|w| w.addr.clone()).collect();
    let run = run_cluster_cfg(&addrs, &fx.job, &fx.raw, CHUNK, cfg);
    for w in pool {
        w.stop();
    }
    run
}

fn assert_recovered(fx: &Fixture, run: piper::net::cluster::ClusterRun, what: &str) {
    assert_eq!(run.processed, fx.want, "{what}: output must be bit-identical to fault-free");
    assert_eq!(run.stats.rows, fx.rows, "{what}");
    assert!(run.retries >= 1, "{what}: recovery must go through the retry path");
    assert!(run.faults >= 1, "{what}: the injected fault must be observed");
}

/// A worker that crashes mid-pass-1 (connection severed while the shard
/// streams in) costs one retry, not the job — and not a bit of output.
#[test]
fn crash_mid_pass1_recovers_bit_identical() {
    let fx = fixture(240);
    let run = run_with_fault_on_first_session(
        &fx,
        3,
        FaultPlan::crash_after_rx(2), // dies while reading shard chunks
        &chaos_cfg(),
    )
    .expect("cluster must survive a mid-pass-1 crash");
    assert_recovered(&fx, run, "crash mid-pass-1");
}

/// A worker that crashes mid-pass-2 (results already flowing) forces
/// the fresh-session retry path: `Job → Pass1End → VocabLoad → Pass2…`
/// on a surviving worker, skipping pass 1 entirely.
#[test]
fn crash_mid_pass2_recovers_bit_identical() {
    let fx = fixture(240);
    let run = run_with_fault_on_first_session(
        &fx,
        3,
        // tx frame 0 is the VocabDump (pass 1 completes), tx frame 1 the
        // first ResultChunk — the crash lands squarely in pass 2.
        FaultPlan::crash_after_tx(1),
        &chaos_cfg(),
    )
    .expect("cluster must survive a mid-pass-2 crash");
    assert_recovered(&fx, run, "crash mid-pass-2");
}

/// A silently dropped data frame cannot corrupt output: the per-shard
/// row-count verification (or the spliced-row decode it causes) turns
/// it into a typed, retryable error and the shard re-dispatches.
#[test]
fn dropped_frame_is_detected_and_retried() {
    let fx = fixture(240);
    let run = run_with_fault_on_first_session(
        &fx,
        3,
        // rx frame 0 is the Job header; frame 1 is the first Pass1Chunk.
        FaultPlan::clean().with_rx(1, FaultKind::DropFrame),
        &chaos_cfg(),
    )
    .expect("a dropped frame must be detected, never silently absorbed");
    assert_recovered(&fx, run, "dropped frame");
}

/// A flipped bit on the wire is caught by the frame checksum — the
/// worker refuses the frame, the shard retries elsewhere.
#[test]
fn corrupt_frame_is_detected_and_retried() {
    let fx = fixture(240);
    let run = run_with_fault_on_first_session(
        &fx,
        3,
        FaultPlan::clean().with_rx(2, FaultKind::Corrupt { offset: 7, xor: 0x40 }),
        &chaos_cfg(),
    )
    .expect("a corrupt frame must be detected, never silently absorbed");
    assert_recovered(&fx, run, "corrupt frame");
}

/// Jitter below the deadlines is absorbed, not retried: the run stays
/// clean and the retry counters stay zero.
#[test]
fn delay_below_deadline_is_absorbed() {
    let fx = fixture(120);
    let plan = FaultPlan::clean()
        .with_rx(1, FaultKind::Delay { dur: ms(30) })
        .with_rx(3, FaultKind::Delay { dur: ms(30) });
    let run = run_with_fault_on_first_session(&fx, 2, plan, &chaos_cfg())
        .expect("sub-deadline jitter must not fail the run");
    assert_eq!(run.processed, fx.want);
    assert_eq!((run.retries, run.faults), (0, 0), "no retry for mere jitter");
}

/// A wedged worker (delay far past the I/O deadline) is a timeout, and
/// a timeout is just another retryable shard failure.
#[test]
fn hung_worker_times_out_and_recovers() {
    let fx = fixture(120);
    let mut cfg = chaos_cfg();
    cfg.io_timeout = Some(ms(300));
    let run = run_with_fault_on_first_session(
        &fx,
        2,
        FaultPlan::clean().with_rx(0, FaultKind::Delay { dur: ms(1500) }),
        &cfg,
    )
    .expect("a hung worker must cost a timeout retry, not the job");
    assert_recovered(&fx, run, "hung worker");
}

/// When every attempt fails, the job fails *cleanly*: a typed, retryable
/// [`NetError`] well inside the job deadline — the no-hang guarantee.
#[test]
fn exhausted_retries_fail_typed_within_deadline() {
    let fx = fixture(120);
    let mut cfg = chaos_cfg();
    cfg.io_timeout = Some(ms(400));
    cfg.retries = 2;
    cfg.job_deadline = Some(Duration::from_secs(20));
    // Single worker, every session crashes on the first read.
    let plans = vec![FaultPlan::crash_after_rx(0); 8];
    let w = ChaosWorker::spawn(plans);
    let addrs = vec![w.addr.clone()];
    let start = Instant::now();
    let err = run_cluster_cfg(&addrs, &fx.job, &fx.raw, CHUNK, &cfg)
        .expect_err("no surviving attempt must fail the job");
    let elapsed = start.elapsed();
    w.stop();
    let net = NetError::of(&err).unwrap_or_else(|| panic!("untyped error: {err:#}"));
    assert!(net.retryable(), "exhaustion root cause should be transport-class, got {net}");
    assert!(
        format!("{err:#}").contains("retries exhausted"),
        "the context names the exhausted retry budget: {err:#}"
    );
    assert!(
        elapsed < Duration::from_secs(20),
        "failure must land inside the deadline, took {elapsed:?}"
    );
}

/// With every worker's process gone (connects refused), the run reports
/// it as typed [`NetError::PeerGone`] naming the situation — fast, no
/// per-attempt socket timeouts.
#[test]
fn no_surviving_workers_is_a_typed_peer_gone() {
    let fx = fixture(60);
    // Bind then drop: the ports exist but refuse connections.
    let dead_addr = || {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        l.local_addr().expect("addr").to_string()
    };
    let addrs = vec![dead_addr(), dead_addr()];
    let start = Instant::now();
    let err = run_cluster_cfg(&addrs, &fx.job, &fx.raw, CHUNK, &chaos_cfg())
        .expect_err("dead cluster must fail");
    assert!(
        matches!(NetError::of(&err), Some(NetError::PeerGone { .. })),
        "expected PeerGone, got {err:#}"
    );
    assert!(
        format!("{err:#}").contains("no surviving workers"),
        "the error names the dead cluster: {err:#}"
    );
    assert!(start.elapsed() < Duration::from_secs(5), "struck workers fail fast");
}

/// An application error on the worker (here: a spec whose selector the
/// schema can't satisfy) travels back *verbatim* as the `ErrorReply`
/// payload and surfaces from `run_cluster` as a typed
/// [`NetError::JobFailed`] carrying the worker's address and reason.
#[test]
fn worker_error_reply_content_surfaces_from_run_cluster() {
    let ds = SynthDataset::generate(SynthConfig::small(40));
    let spec = PipelineSpec::parse("sparse[40]: modulus:7|genvocab|applyvocab")
        .expect("parses; the selector only fails against this schema");
    let raw = utf8::encode_dataset(&ds);
    let job =
        Job { schema: ds.schema(), spec, format: WireFormat::Utf8, errors: Default::default() };
    let mut cfg = chaos_cfg();
    cfg.retries = 0; // the error is deterministic — retrying can't cure it
    let err = run_cluster_loopback_cfg(2, &job, &raw, CHUNK, &cfg)
        .expect_err("an uncompilable job must fail");
    match NetError::of(&err) {
        Some(NetError::JobFailed { worker, reason }) => {
            assert!(worker.starts_with("127.0.0.1:"), "worker address travels: {worker}");
            assert!(
                reason.contains("selector") || reason.contains("sparse"),
                "the worker's own message travels verbatim: {reason:?}"
            );
        }
        other => panic!("expected JobFailed, got {other:?}: {err:#}"),
    }
}

/// Seeded fuzz sweep: with one flaky node in a 3-worker cluster, every
/// seeded fault plan — whatever mix of drop/corrupt/truncate/delay/close
/// it scripts — must end in a bit-identical run. The plans are data
/// (same seed → same plan), so any failing seed reproduces exactly.
#[test]
fn seeded_fault_sweep_recovers_on_every_seed() {
    let fx = fixture(180);
    let cfg = chaos_cfg();
    for seed in 0..12u64 {
        let plan = FaultPlan::seeded(seed);
        let run = run_with_fault_on_first_session(&fx, 3, plan.clone(), &cfg)
            .unwrap_or_else(|e| panic!("seed {seed} (plan {plan:?}) failed: {e:#}"));
        assert_eq!(
            run.processed, fx.want,
            "seed {seed} (plan {plan:?}): output diverged from fault-free"
        );
        assert_eq!(run.stats.rows, fx.rows, "seed {seed}");
    }
}

/// Serving path: a session severed mid-request surfaces as a typed,
/// retryable transport error on the client — the signal
/// [`ServeClient::connect_retry`] needs to reconnect.
#[test]
fn severed_serve_session_is_a_typed_transport_error() {
    let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").expect("spec");
    let artifact =
        VocabArtifact::new(spec, Schema::new(1, 1), vec![vec![5, 12]]).expect("artifact");
    let job = ServeJob {
        policy: MissPolicy::Sentinel,
        format: WireFormat::Utf8,
        queue_depth: 4,
        artifact,
    };
    // rx frame 0 is the ServeJob header (session opens fine); frame 1 —
    // the first request — severs the connection.
    let w = ChaosWorker::spawn(vec![FaultPlan::crash_after_rx(1)]);
    let mut client = ServeClient::connect(&w.addr, &job).expect("session opens");
    let err = client.request(b"1,2,3\n").expect_err("severed session must error");
    w.stop();
    let net = NetError::of(&err).unwrap_or_else(|| panic!("untyped error: {err:#}"));
    assert!(
        net.retryable(),
        "a severed serve session must be retryable (reconnect), got {net}"
    );
}

/// Serving path: connect-retry against a dead address gives up with a
/// typed error and the retry budget in the context — quickly.
#[test]
fn serve_connect_retry_fails_typed_when_no_worker_listens() {
    let spec = PipelineSpec::parse("modulus:97|genvocab|applyvocab").expect("spec");
    let artifact =
        VocabArtifact::new(spec, Schema::new(1, 1), vec![vec![5, 12]]).expect("artifact");
    let job = ServeJob {
        policy: MissPolicy::Sentinel,
        format: WireFormat::Utf8,
        queue_depth: 4,
        artifact,
    };
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        l.local_addr().expect("addr").to_string()
    };
    let mut cfg = chaos_cfg();
    cfg.retries = 1;
    let start = Instant::now();
    let err = ServeClient::connect_retry(&dead, &job, &cfg)
        .expect_err("nothing listens — connect must fail");
    assert!(
        matches!(NetError::of(&err), Some(NetError::PeerGone { .. })),
        "expected PeerGone, got {err:#}"
    );
    assert!(format!("{err:#}").contains("retries exhausted"), "{err:#}");
    assert!(start.elapsed() < Duration::from_secs(5));
}
