//! Wire-decoder fuzz suite: seeded random byte soup, truncations and
//! single-byte corruptions of valid encodings, fed into every protocol
//! decoder — the frame reader, the classic job/vocab/result codecs and
//! the full service frame set (tags 19–25).
//!
//! The contract being pinned (the service trusts it everywhere): a
//! decoder handed hostile bytes returns a typed `Err` — it never
//! panics, never over-allocates past the bytes actually present, and
//! never silently reconstructs the original value from a strict prefix.
//! All randomness flows through the repo's seeded [`XorShift64`], so a
//! failing input reproduces exactly from the printed seed.

use piper::data::row::ProcessedRow;
use piper::data::Schema;
use piper::net::protocol::{
    self, frame_sum, read_frame, write_frame, IndexBatch, Job, KeyBatch, KeyHello, OwnerSeed,
    RunStats, ServiceHello, ServiceOpen, SplitAssign, SplitDone, SplitStatus, Tag, VocabDelta,
    FRAME_HEADER_BYTES,
};
use piper::net::stream::WireFormat;
use piper::net::NetError;
use piper::ops::Modulus;
use piper::util::prng::XorShift64;

/// One decoder under test: a valid encoding plus a closure that decodes
/// a buffer and reports whether the result equals the original value.
/// `strict` marks codecs whose framing rejects *every* proper prefix
/// (fixed length or trailing-bytes check).
struct Case {
    name: &'static str,
    bytes: Vec<u8>,
    strict: bool,
    decode: Box<dyn Fn(&[u8]) -> Result<bool, ()>>,
}

fn case<T, D>(name: &'static str, bytes: Vec<u8>, strict: bool, original: T, decode: D) -> Case
where
    T: PartialEq + 'static,
    D: Fn(&[u8]) -> anyhow::Result<T> + 'static,
{
    Case {
        name,
        bytes,
        strict,
        decode: Box::new(move |buf| match decode(buf) {
            Ok(v) => Ok(v == original),
            Err(_) => Err(()),
        }),
    }
}

fn schema() -> Schema {
    Schema::new(2, 3)
}

fn sample_job() -> Job {
    Job::dlrm(schema(), Modulus::new(1000), WireFormat::Utf8)
}

fn sample_rows() -> Vec<ProcessedRow> {
    vec![
        ProcessedRow { label: 1, dense: vec![0.5, 1.5], sparse: vec![3, 0, 7] },
        ProcessedRow { label: 0, dense: vec![2.5, -3.5], sparse: vec![9, 2, 1] },
    ]
}

fn sample_stats() -> RunStats {
    RunStats {
        rows: 100,
        vocab_entries: 17,
        rows_skipped: 2,
        rows_quarantined: 1,
        illegal_bytes: 5,
        decode_ns: 1_000,
        stateless_ns: 2_000,
        vocab_ns: 3_000,
    }
}

/// Every payload decoder in the protocol, seeded with a valid encoding.
fn cases() -> Vec<Case> {
    let job = sample_job();
    let hello = ServiceHello {
        job_id: 7,
        worker_id: 1,
        epoch: 2,
        owners: vec![0, 1, 0],
        peers: vec!["127.0.0.1:9001".to_string(), "127.0.0.1:9002".to_string()],
        decode_threads: 2,
        job: job.clone(),
    };
    let keys = ServiceOpen::Keys(KeyHello { job_id: 7, owner_id: 0, requester_id: 1 });
    let ack = ServiceOpen::Ack { worker_id: 3 };
    let assign = SplitAssign { seq: 5, epoch: 1, expected_rows: 100, owners: vec![1, 0, 1] };
    let kb = KeyBatch { col: 2, seq: 5, keys: vec![0xDEAD_BEEF, 0, 42] };
    let ib = IndexBatch { col: 2, seq: 5, indices: vec![11, 0, 7] };
    let delta = VocabDelta { col: 1, seq: 3, keys: vec![1, 2, 3], indices: vec![0, 1, 2] };
    let seed = OwnerSeed { col: 0, next_seq: 4, keys: vec![9, 8, 7, 6] };
    let done_ok = SplitDone { seq: 9, status: SplitStatus::Ok(sample_stats()) };
    let done_failed =
        SplitDone { seq: 9, status: SplitStatus::Failed("decode blew the error budget".into()) };
    let vocabs = vec![vec![1u32, 2, 3], vec![], vec![9, 9]];
    let rows = sample_rows();
    let sch = schema();

    vec![
        case("job", job.encode(), false, job.clone(), Job::decode),
        case(
            "service_open_dispatch",
            ServiceOpen::Dispatch(hello.clone()).encode(),
            false,
            ServiceOpen::Dispatch(hello),
            ServiceOpen::decode,
        ),
        case("service_open_keys", keys.encode(), true, keys.clone(), ServiceOpen::decode),
        case("service_open_ack", ack.encode(), true, ack.clone(), ServiceOpen::decode),
        case("split_assign", assign.encode(), true, assign.clone(), SplitAssign::decode),
        case("key_batch", kb.encode(), true, kb.clone(), KeyBatch::decode),
        case("index_batch", ib.encode(), true, ib.clone(), IndexBatch::decode),
        case("vocab_delta", delta.encode(), true, delta.clone(), VocabDelta::decode),
        case("owner_seed", seed.encode(), true, seed.clone(), OwnerSeed::decode),
        case("split_done_ok", done_ok.encode(), true, done_ok.clone(), SplitDone::decode),
        case("split_done_failed", done_failed.encode(), false, done_failed.clone(), SplitDone::decode),
        case("run_stats", sample_stats().encode(), true, sample_stats(), RunStats::decode),
        case("vocabs", protocol::pack_vocabs(&vocabs), true, vocabs.clone(), protocol::unpack_vocabs),
        case(
            "shard_dump",
            protocol::pack_shard_dump(42, &vocabs),
            true,
            (42u64, vocabs),
            protocol::unpack_shard_dump,
        ),
        case("rows", protocol::pack_rows(&rows, sch), false, rows.clone(), move |b| {
            protocol::unpack_rows(b, sch)
        }),
        case(
            "service_rows",
            protocol::pack_service_rows(3, &rows, sch),
            false,
            (3u64, rows),
            move |b| protocol::unpack_service_rows(b, sch),
        ),
    ]
}

#[test]
fn valid_encodings_roundtrip() {
    for c in cases() {
        assert_eq!((c.decode)(&c.bytes), Ok(true), "{}: roundtrip must reproduce the value", c.name);
    }
}

#[test]
fn truncated_encodings_error_or_shrink() {
    // Every proper prefix: strict codecs must reject it outright; the
    // rest may accept it (e.g. a result chunk that happens to stay
    // row-aligned) but must never reconstruct the original value.
    for c in cases() {
        for cut in 0..c.bytes.len() {
            match (c.decode)(&c.bytes[..cut]) {
                Err(()) => {}
                Ok(eq) => {
                    assert!(!c.strict, "{}: accepted a {cut}-byte prefix of {} bytes", c.name, c.bytes.len());
                    assert!(!eq, "{}: a {cut}-byte prefix reconstructed the full value", c.name);
                }
            }
        }
    }
}

#[test]
fn corrupted_encodings_never_panic() {
    // Single- and multi-byte XOR corruption at seeded-random offsets,
    // optionally combined with a truncation. Any outcome but a panic
    // (or runaway allocation, which the harness would OOM on) is fine.
    let mut rng = XorShift64::new(0xF0A2);
    for c in cases() {
        for _ in 0..400 {
            let mut buf = c.bytes.clone();
            if buf.is_empty() {
                continue;
            }
            for _ in 0..=rng.below(2) {
                let at = rng.below(buf.len() as u64) as usize;
                buf[at] ^= 1 + rng.below(255) as u8;
            }
            if rng.chance(0.3) {
                buf.truncate(rng.below(buf.len() as u64 + 1) as usize);
            }
            let _ = (c.decode)(&buf);
        }
    }
}

#[test]
fn random_soup_never_panics() {
    // Pure byte soup of varying lengths into every payload decoder.
    let mut rng = XorShift64::new(0xB00B5);
    for _ in 0..600 {
        let len = rng.below(300) as usize;
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        for c in cases() {
            let _ = (c.decode)(&buf);
        }
    }
}

#[test]
fn tag_byte_space_is_fully_classified() {
    for v in 0u8..=255 {
        let ok = Tag::from_u8(v).is_ok();
        assert_eq!(ok, (1..=25).contains(&v), "tag byte {v}");
    }
}

#[test]
fn frame_reader_rejects_soup_and_truncation() {
    let mut rng = XorShift64::new(0xCAFE);
    // Random headers (payload length masked to 16 bits so a hostile
    // length can't demand a giant zeroed buffer from the test) followed
    // by too few payload bytes: header decode, the frame cap, checksum
    // or EOF must reject every one.
    for _ in 0..400 {
        let mut buf = vec![rng.next_u64() as u8];
        let len = 1 + rng.below((1 << 16) - 1);
        buf.extend_from_slice(&len.to_le_bytes());
        buf.extend_from_slice(&(rng.next_u64() as u32).to_le_bytes());
        let short = rng.below(len + 1) as usize;
        buf.extend((0..short.saturating_sub(1)).map(|_| rng.next_u64() as u8));
        assert!(read_frame(&mut &buf[..]).is_err());
    }
    // Truncating a valid frame stream at every byte boundary.
    let mut frame = Vec::new();
    write_frame(&mut frame, Tag::KeyBatch, &KeyBatch { col: 1, seq: 2, keys: vec![3, 4] }.encode())
        .unwrap();
    for cut in 0..frame.len() {
        let err = read_frame(&mut &frame[..cut]).unwrap_err();
        assert!(NetError::of(&err).is_some(), "truncation at {cut}: untyped error {err:#}");
    }
}

#[test]
fn frame_bit_flips_are_caught_by_the_checksum() {
    // Flip one byte anywhere in a valid frame: tag, low length bytes,
    // checksum or payload. The read must fail (checksum mismatch, bad
    // tag, cap or EOF) — corruption never passes through silently.
    // Length-byte flips stay in the low three bytes so a corrupt length
    // is bounded (< 16 MiB) before the cap/EOF rejects it.
    let payload = VocabDelta { col: 1, seq: 3, keys: vec![1, 2], indices: vec![0, 1] }.encode();
    let mut frame = Vec::new();
    write_frame(&mut frame, Tag::VocabDelta, &payload).unwrap();
    let mut rng = XorShift64::new(0x51DE);
    for at in 0..frame.len() {
        if (4..FRAME_HEADER_BYTES - 4).contains(&at) {
            continue; // high length bytes: covered by the cap test below
        }
        let mut buf = frame.clone();
        buf[at] ^= 1 + rng.below(255) as u8;
        assert!(read_frame(&mut &buf[..]).is_err(), "byte {at} flip must not decode");
    }
    // A length field past MAX_FRAME is rejected before any allocation.
    let mut buf = frame.clone();
    buf[8] = 0xFF; // top length byte -> ~2^63 bytes claimed
    let err = read_frame(&mut &buf[..]).unwrap_err();
    assert!(
        matches!(NetError::of(&err), Some(NetError::Malformed { .. })),
        "oversized frame must be Malformed, got {err:#}"
    );
    // Sanity: the checksum actually covers the tag byte.
    assert_ne!(frame_sum(1, &payload), frame_sum(2, &payload));
}
