//! Scale-out acceptance suite for the disaggregated preprocessing
//! service: N workers with shard-owned vocabularies must produce output
//! **bit-identical** to a single sequential scan, with no global
//! vocabulary barrier anywhere on the wire, surviving scripted worker
//! departure, concurrent jobs on one pool, and window backpressure.
//!
//! The wire assertions run through a frame-parsing TCP proxy so the
//! dispatcher, the workers and the worker-to-worker key sessions are
//! all the production code path — the proxy only records tag bytes.

use std::collections::HashSet;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use piper::data::row::ProcessedColumns;
use piper::data::{binary, utf8, SynthConfig, SynthDataset};
use piper::net::cluster::shard_rows;
use piper::net::fault::FaultPlan;
use piper::net::protocol::{Job, Tag, FRAME_HEADER_BYTES, MAX_FRAME};
use piper::net::stream::WireFormat;
use piper::net::worker::{self, ShutdownHandle, WorkerOptions};
use piper::net::NetConfig;
use piper::ops::PipelineSpec;
use piper::service::{run_service_cfg, run_service_loopback, ServiceConfig, ServiceRun};

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

/// Fast-failing knobs for the departure tests: every blocking step is
/// bounded in hundreds of ms so a regression fails, not wedges, CI.
fn fast_cfg(window: usize) -> ServiceConfig {
    ServiceConfig {
        net: NetConfig {
            io_timeout: Some(ms(2000)),
            job_deadline: Some(Duration::from_secs(30)),
            retries: 2,
            backoff: ms(10),
            backoff_cap: ms(100),
            leader_window: 1,
        },
        window,
        decode_threads: 0,
        chunk_bytes: 512,
    }
}

struct Fixture {
    job: Job,
    raw: Vec<u8>,
    want: ProcessedColumns,
    rows: u64,
}

fn fixture(rows: usize, format: WireFormat, spec_text: &str) -> Fixture {
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let spec = PipelineSpec::parse(spec_text).expect("spec parses");
    let want = spec.execute(&ds.rows, ds.schema()).expect("sequential reference");
    let raw = match format {
        WireFormat::Utf8 => utf8::encode_dataset(&ds),
        WireFormat::Binary => binary::encode_dataset(&ds),
    };
    let job = Job { schema: ds.schema(), spec, format, errors: Default::default() };
    Fixture { job, raw, want, rows: ds.rows.len() as u64 }
}

const DLRM: &str = "sparse[*]: modulus:997|genvocab|applyvocab; dense[*]: neg2zero|log";

fn assert_clean(fx: &Fixture, run: &ServiceRun, what: &str) {
    assert_eq!(run.processed, fx.want, "{what}: must equal the sequential scan");
    assert_eq!(run.stats.rows, fx.rows, "{what}");
    assert_eq!((run.retries, run.faults), (0, 0), "{what}: clean run retries nothing");
}

#[test]
fn sizes_and_formats_agree_with_sequential_scan() {
    for format in [WireFormat::Utf8, WireFormat::Binary] {
        let fx = fixture(240, format, DLRM);
        for n in [1usize, 2, 4] {
            let run = run_service_loopback(n, &fx.job, &fx.raw, &ServiceConfig::default())
                .expect("service run");
            assert_clean(&fx, &run, &format!("{n} workers, {format:?}"));
            assert_eq!(run.workers, n);
            assert!(
                run.max_inflight <= n,
                "window 0 means one split per live worker, saw {}",
                run.max_inflight
            );
            let splits: u64 = run.per_worker.iter().map(|w| w.splits).sum();
            assert_eq!(splits, run.per_worker.len() as u64, "one split per worker by default");
        }
    }
}

/// Per-column programs shard across owners too: applied and gen-only
/// vocabularies, a stateless modulus column and dense-only ops all
/// agree with the sequential reference at every cluster size.
#[test]
fn heterogeneous_spec_agrees_with_sequential_scan() {
    let fx = fixture(
        200,
        WireFormat::Utf8,
        "sparse[*]: modulus:997|genvocab|applyvocab; \
         sparse[0..4]: modulus:101|genvocab|applyvocab; \
         sparse[5]: modulus:53; \
         sparse[6]: modulus:61|genvocab; \
         dense[*]: neg2zero|log; \
         dense[1]: clip:0:50|bucketize:2:8:32",
    );
    for n in [1usize, 3] {
        let run = run_service_loopback(n, &fx.job, &fx.raw, &ServiceConfig::default())
            .expect("service run");
        assert_clean(&fx, &run, &format!("{n} workers, heterogeneous"));
    }
}

// ---------------------------------------------------------------------
// Wire-level assertions: a frame-parsing proxy in front of every worker
// ---------------------------------------------------------------------

/// Pump frames one way, recording each tag byte. Frames are
/// self-delimiting (`tag:u8 len:u64le sum:u32le payload`), so the proxy
/// never needs protocol state; EOF or a bogus length severs both sides.
fn pump_frames(mut from: TcpStream, mut to: TcpStream, tags: &Mutex<HashSet<u8>>) {
    let sever = |a: &TcpStream, b: &TcpStream| {
        let _ = a.shutdown(std::net::Shutdown::Both);
        let _ = b.shutdown(std::net::Shutdown::Both);
    };
    loop {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        if from.read_exact(&mut header).is_err() {
            sever(&from, &to);
            return;
        }
        tags.lock().unwrap().insert(header[0]);
        let len = u64::from_le_bytes([
            header[1], header[2], header[3], header[4],
            header[5], header[6], header[7], header[8],
        ]);
        if len > MAX_FRAME || to.write_all(&header).is_err() {
            sever(&from, &to);
            return;
        }
        let mut left = len as usize;
        let mut buf = [0u8; 16 << 10];
        while left > 0 {
            let take = left.min(buf.len());
            if from.read_exact(&mut buf[..take]).is_err() || to.write_all(&buf[..take]).is_err() {
                sever(&from, &to);
                return;
            }
            left -= take;
        }
        if to.flush().is_err() {
            sever(&from, &to);
            return;
        }
    }
}

/// A recording proxy in front of `target`. The accept loop thread is
/// deliberately leaked — it dies with the test process.
fn spawn_proxy(target: String, tags: Arc<Mutex<HashSet<u8>>>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
    let addr = listener.local_addr().expect("addr").to_string();
    std::thread::spawn(move || loop {
        let Ok((client, _)) = listener.accept() else { return };
        let Ok(upstream) = TcpStream::connect(&target) else { return };
        let (c2, u2) = (client.try_clone().unwrap(), upstream.try_clone().unwrap());
        let (ta, tb) = (tags.clone(), tags.clone());
        std::thread::spawn(move || pump_frames(client, upstream, &ta));
        std::thread::spawn(move || pump_frames(u2, c2, &tb));
    });
    addr
}

/// The architectural claim on the wire: the service path carries its
/// own frames (hello, split assign, key/index batches, vocab deltas)
/// and **none** of the two-pass barrier frames — no `Pass1End`, no
/// `VocabSync`/`VocabDump`, no `VocabLoad`. Both the dispatcher→worker
/// sessions and the worker→worker key sessions cross the proxies,
/// because the peer table the workers receive is the proxy addresses.
#[test]
fn wire_carries_service_frames_and_no_barrier() {
    let fx = fixture(240, WireFormat::Utf8, DLRM);
    let tags = Arc::new(Mutex::new(HashSet::new()));

    let mut shutdowns = Vec::new();
    let mut handles = Vec::new();
    let mut proxied = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        let real = listener.local_addr().expect("addr").to_string();
        let shutdown = ShutdownHandle::new(&listener).expect("shutdown handle");
        shutdowns.push(shutdown.clone());
        handles.push(std::thread::spawn(move || {
            worker::serve_until(&listener, &shutdown, &WorkerOptions::default())
        }));
        proxied.push(spawn_proxy(real, tags.clone()));
    }

    let splits = shard_rows(&fx.raw, fx.job.schema, false, 4);
    assert!(splits.len() >= 2, "need multiple splits in flight");
    let run = run_service_cfg(&proxied, &fx.job, &fx.raw, &splits, &fast_cfg(0))
        .expect("service run through proxies");
    for s in &shutdowns {
        s.shutdown();
    }
    for h in handles {
        h.join().expect("worker thread").expect("worker exits clean");
    }
    assert_eq!(run.processed, fx.want, "proxied run must equal the sequential scan");

    let seen = tags.lock().unwrap().clone();
    for must in [Tag::ServiceHello, Tag::SplitAssign, Tag::KeyBatch, Tag::IndexBatch,
                 Tag::VocabDelta, Tag::SplitDone, Tag::FusedChunk, Tag::FusedEnd] {
        assert!(seen.contains(&(must as u8)), "expected {must:?} on the wire, saw {seen:?}");
    }
    for never in [Tag::Pass1Chunk, Tag::Pass1End, Tag::Pass2Chunk, Tag::Pass2End,
                  Tag::VocabSync, Tag::VocabDump, Tag::VocabLoad] {
        assert!(!seen.contains(&(never as u8)), "barrier frame {never:?} crossed the wire");
    }
}

// ---------------------------------------------------------------------
// Scripted worker departure
// ---------------------------------------------------------------------

fn worker_opts() -> WorkerOptions {
    WorkerOptions { io_timeout: Some(ms(2000)), serve_idle_timeout: None }
}

/// One session: real socket, real session loop, fault plan in between
/// (same harness as the chaos suite).
fn serve_faulty(stream: TcpStream, plan: &FaultPlan, opts: &WorkerOptions) -> piper::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(opts.io_timeout)?;
    stream.set_write_timeout(opts.io_timeout)?;
    let reader = BufReader::with_capacity(1 << 16, stream.try_clone()?);
    let writer = BufWriter::with_capacity(1 << 16, stream.try_clone()?);
    let (mut fr, mut fw, _hooks) = plan.wrap(reader, writer);
    worker::handle_connection(&mut fr, &mut fw, opts, Some(&stream)).map(|_| ())
}

/// A worker whose first session follows `plan`; every later session
/// (the rejoin, key sessions from peers) runs clean. `one_shot` models
/// a process death: the listener is dropped after the first session, so
/// the rejoin attempt is refused and the dispatcher must strike.
struct ScriptedWorker {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl ScriptedWorker {
    fn spawn(plan: FaultPlan, one_shot: bool) -> ScriptedWorker {
        let opts = worker_opts();
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            if one_shot {
                // Process-death model: one session, then the listener is
                // gone *before* the session dies, so every reconnect
                // attempt is refused outright.
                if let Ok((stream, _)) = listener.accept() {
                    drop(listener);
                    let _ = serve_faulty(stream, &plan, &opts);
                }
                return;
            }
            let mut session = 0usize;
            let mut inflight = Vec::new();
            loop {
                let Ok((stream, _)) = listener.accept() else { break };
                if stop2.load(Ordering::Acquire) {
                    break; // the poison pill
                }
                let plan = if session == 0 { plan.clone() } else { FaultPlan::clean() };
                session += 1;
                inflight.push(std::thread::spawn(move || {
                    let _ = serve_faulty(stream, &plan, &opts);
                }));
            }
            for t in inflight {
                let _ = t.join();
            }
        });
        ScriptedWorker { addr, stop, thread }
    }

    fn stop(self) {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept loop (ignored if the listener is gone).
        if let Ok(sock) = self.addr.parse() {
            let _ = TcpStream::connect_timeout(&sock, Duration::from_secs(1));
        }
        let _ = self.thread.join();
    }
}

/// Transient departure: worker 0's dispatch session is severed mid-way
/// through streaming split 0 (the first dispatch always lands on worker
/// 0). The worker process stays alive, so the dispatcher rejoins it and
/// re-dispatches the split — recovery, not strike.
#[test]
fn transient_session_loss_recovers_bit_identical() {
    let fx = fixture(240, WireFormat::Utf8, DLRM);
    let pool = vec![
        ScriptedWorker::spawn(FaultPlan::crash_after_rx(4), false),
        ScriptedWorker::spawn(FaultPlan::clean(), false),
    ];
    let addrs: Vec<String> = pool.iter().map(|w| w.addr.clone()).collect();
    let splits = shard_rows(&fx.raw, fx.job.schema, false, 4);
    let run = run_service_cfg(&addrs, &fx.job, &fx.raw, &splits, &fast_cfg(0));
    let run = run.expect("session loss must be recovered");
    for w in pool {
        w.stop();
    }
    assert_eq!(run.processed, fx.want, "recovered run must equal the sequential scan");
    assert!(run.retries >= 1, "recovery must be visible as a retry");
    assert!(run.faults >= 1, "the severed session must be counted as a fault");
}

/// Permanent departure: worker 0 dies after its first session and
/// refuses reconnection. The dispatcher must strike it, transfer its
/// column ownership to the survivor, seed the new owner from the
/// vocabulary mirror, replay what the transfer invalidated — and still
/// produce the sequential-scan answer.
#[test]
fn permanent_departure_strikes_and_transfers_ownership() {
    let fx = fixture(240, WireFormat::Utf8, DLRM);
    let pool = vec![
        ScriptedWorker::spawn(FaultPlan::crash_after_rx(4), true),
        ScriptedWorker::spawn(FaultPlan::clean(), false),
    ];
    let addrs: Vec<String> = pool.iter().map(|w| w.addr.clone()).collect();
    let splits = shard_rows(&fx.raw, fx.job.schema, false, 4);
    let run = run_service_cfg(&addrs, &fx.job, &fx.raw, &splits, &fast_cfg(0));
    let run = run.expect("one dead worker out of two must not fail the job");
    for w in pool {
        w.stop();
    }
    assert_eq!(run.processed, fx.want, "post-strike run must equal the sequential scan");
    assert!(run.faults >= 1, "the death must be counted");
    let survivor = run.per_worker.iter().map(|w| w.splits).max().unwrap_or(0);
    assert!(survivor >= splits.len() as u64 - 1, "the survivor must win the remaining splits");
}

// ---------------------------------------------------------------------
// Multiplexing and backpressure
// ---------------------------------------------------------------------

/// Two jobs with different specs and datasets share one worker pool
/// concurrently; per-job state is keyed by job id, so both must come
/// out bit-identical.
#[test]
fn concurrent_jobs_share_one_pool() {
    let fx_a = fixture(180, WireFormat::Utf8, DLRM);
    let fx_b = fixture(
        130,
        WireFormat::Binary,
        "sparse[*]: modulus:499|genvocab|applyvocab; dense[*]: neg2zero|log",
    );

    let mut shutdowns = Vec::new();
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(listener.local_addr().expect("addr").to_string());
        let shutdown = ShutdownHandle::new(&listener).expect("shutdown handle");
        shutdowns.push(shutdown.clone());
        handles.push(std::thread::spawn(move || {
            worker::serve_until(&listener, &shutdown, &WorkerOptions::default())
        }));
    }

    let (run_a, run_b): (piper::Result<ServiceRun>, piper::Result<ServiceRun>) =
        std::thread::scope(|s| {
            let ha = s.spawn(|| {
                let splits = shard_rows(&fx_a.raw, fx_a.job.schema, false, 3);
                run_service_cfg(&addrs, &fx_a.job, &fx_a.raw, &splits, &fast_cfg(0))
            });
            let hb = s.spawn(|| {
                let splits = shard_rows(&fx_b.raw, fx_b.job.schema, true, 3);
                run_service_cfg(&addrs, &fx_b.job, &fx_b.raw, &splits, &fast_cfg(0))
            });
            (ha.join().expect("job thread"), hb.join().expect("job thread"))
        });
    for s in &shutdowns {
        s.shutdown();
    }
    for h in handles {
        h.join().expect("worker thread").expect("worker exits clean");
    }

    assert_clean(&fx_a, &run_a.expect("job A completes"), "job A (utf8)");
    assert_clean(&fx_b, &run_b.expect("job B completes"), "job B (binary)");
}

/// `window = 1` is strict backpressure: never more than one split in
/// flight across the whole cluster, and the answer is unchanged.
#[test]
fn window_one_serializes_dispatch() {
    let fx = fixture(200, WireFormat::Utf8, DLRM);
    let run = run_service_loopback(2, &fx.job, &fx.raw, &fast_cfg(1)).expect("service run");
    assert_clean(&fx, &run, "window=1");
    assert_eq!(run.max_inflight, 1, "window=1 must cap concurrent splits at one");
}
