//! Edge cases and failure injection across the public API: empty and
//! degenerate datasets, malformed wire traffic, adversarial raw bytes,
//! and schema extremes. Nothing here may panic — errors must surface as
//! `Err`, and degenerate-but-legal inputs must round-trip.

use piper::accel::{InputFormat, Mode, PiperConfig};
use piper::coordinator::{run_backend, Backend, Experiment};
use piper::cpu_baseline::{run as cpu_run, BaselineConfig, ConfigKind};
use piper::data::{binary, synth::SynthConfig, utf8, Schema, SynthDataset};
use piper::decode::{ParallelDecoder, ScalarDecoder};
use piper::net::protocol::{read_frame, write_frame, Job, Tag};
use piper::net::stream::{preprocess_buffered, WireFormat};
use piper::pipeline::ExecStrategy;
use piper::ops::Modulus;
use piper::util::XorShift64;

#[test]
fn empty_input_all_backends() {
    let raw: &[u8] = b"";
    let exp = Experiment::new(Modulus::new(97), InputFormat::Utf8);
    for b in [
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ] {
        let s = run_backend(&b, &exp, raw).unwrap();
        assert_eq!(s.rows, 0, "{}", s.backend);
    }
}

#[test]
fn single_row_dataset() {
    let mut cfg = SynthConfig::small(1);
    cfg.schema = Schema::CRITEO;
    let ds = SynthDataset::generate(cfg);
    let raw = utf8::encode_dataset(&ds);
    let r = cpu_run(&BaselineConfig::new(ConfigKind::I, 8, Modulus::new(13)), &raw);
    assert_eq!(r.rows, 1, "8 threads over 1 row must still work");
}

#[test]
fn more_threads_than_rows() {
    let ds = SynthDataset::generate(SynthConfig::small(5));
    let raw = binary::encode_dataset(&ds);
    let r = cpu_run(&BaselineConfig::new(ConfigKind::III, 64, Modulus::new(13)), &raw);
    assert_eq!(r.rows, 5);
}

#[test]
fn zero_dense_or_zero_sparse_schemas() {
    for schema in [Schema::new(0, 4), Schema::new(4, 0)] {
        let mut cfg = SynthConfig::small(50);
        cfg.schema = schema;
        let ds = SynthDataset::generate(cfg);
        let raw = utf8::encode_dataset(&ds);
        let out = ParallelDecoder::new(schema).decode(&raw);
        assert_eq!(out.rows, ds.rows, "schema {schema:?}");
        // streaming path too, under both strategies (a `[*]` selector
        // over zero columns of a kind resolves to nothing, not an error)
        for strategy in [ExecStrategy::TwoPass, ExecStrategy::Fused] {
            let cols = preprocess_buffered(
                &piper::ops::PipelineSpec::dlrm(7),
                schema,
                WireFormat::Utf8,
                &raw,
                13,
                strategy,
            )
            .unwrap();
            assert_eq!(cols.num_rows(), 50, "{strategy:?}");
        }
    }
}

#[test]
fn adversarial_bytes_never_panic_decoders() {
    let mut rng = XorShift64::new(0xFEED);
    let schema = Schema::new(3, 3);
    for _ in 0..200 {
        let len = rng.below(300) as usize;
        let raw: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let _ = ScalarDecoder::new(schema).decode(&raw);
        let _ = ParallelDecoder::new(schema).decode(&raw);
        // streaming decoder with random chunking
        let _ = preprocess_buffered(
            &piper::ops::PipelineSpec::dlrm(11),
            schema,
            WireFormat::Utf8,
            &raw,
            7,
            ExecStrategy::Fused,
        );
    }
}

#[test]
fn adversarial_binary_streams_error_cleanly() {
    let schema = Schema::CRITEO;
    let mut rng = XorShift64::new(0xFACE);
    for _ in 0..50 {
        let len = rng.below(1000) as usize;
        let raw: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        // must either succeed (if length is row-aligned) or return Err
        let res = preprocess_buffered(
            &piper::ops::PipelineSpec::dlrm(11),
            schema,
            WireFormat::Binary,
            &raw,
            64,
            ExecStrategy::TwoPass,
        );
        if len % schema.binary_row_bytes() == 0 {
            assert!(res.is_ok(), "aligned length {len} should parse");
        } else {
            assert!(res.is_err(), "misaligned length {len} must be rejected");
        }
    }
}

#[test]
fn protocol_rejects_garbage_frames() {
    // random byte soups must never panic the frame reader
    let mut rng = XorShift64::new(0xD0D0);
    for _ in 0..100 {
        let len = rng.below(64) as usize;
        let raw: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let _ = read_frame(&mut &raw[..]);
    }
}

#[test]
fn worker_errors_on_out_of_order_frames() {
    // Pass2 before Pass1End ⇒ the worker must close with an error, and
    // the leader must see a failure, not a hang or panic.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker = std::thread::spawn(move || piper::net::serve_one(&listener));

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = std::io::BufWriter::new(stream);
    let job = Job::dlrm(Schema::new(1, 1), Modulus::new(7), WireFormat::Utf8);
    write_frame(&mut w, Tag::Job, &job.encode()).unwrap();
    write_frame(&mut w, Tag::Pass2Chunk, b"1\t2\taa\n").unwrap();
    use std::io::Write as _;
    w.flush().unwrap();
    let res = worker.join().unwrap();
    assert!(res.is_err(), "worker must reject out-of-order pass frames");
}

#[test]
fn worker_rejects_wrong_first_frame() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker = std::thread::spawn(move || piper::net::serve_one(&listener));
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = std::io::BufWriter::new(stream);
    write_frame(&mut w, Tag::Pass1Chunk, b"hello").unwrap();
    use std::io::Write as _;
    w.flush().unwrap();
    assert!(worker.join().unwrap().is_err());
}

#[test]
fn modulus_one_collapses_vocab() {
    // degenerate modulus: every sparse value maps to 0 → vocab size 1
    let ds = SynthDataset::generate(SynthConfig::small(40));
    let raw = utf8::encode_dataset(&ds);
    let cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::new(1));
    let run = piper::accel::run(&cfg, &raw).unwrap();
    for v in &run.vocabs {
        use piper::ops::Vocab as _;
        assert!(v.len() <= 1);
    }
    for col in &run.processed.sparse {
        assert!(col.iter().all(|&x| x == 0));
    }
}

#[test]
fn huge_thread_count_is_clamped_not_crashing() {
    let ds = SynthDataset::generate(SynthConfig::small(20));
    let raw = utf8::encode_dataset(&ds);
    let r = cpu_run(&BaselineConfig::new(ConfigKind::II, 256, Modulus::new(13)), &raw);
    assert_eq!(r.rows, 20);
}

#[test]
fn rows_with_wrong_column_count_are_tolerated() {
    // short row (missing fields) and long row (extra fields): the decoder
    // fills missing with 0 and drops extras — no panic, row count right.
    let schema = Schema::new(2, 2);
    let raw = b"1\t5\n0\t1\t2\taa\tbb\tcc\tdd\n";
    let out = ScalarDecoder::new(schema).decode(raw);
    assert_eq!(out.rows.len(), 2);
    assert_eq!(out.rows[0].dense, vec![5, 0]);
    assert_eq!(out.rows[1].sparse, vec![0xaa, 0xbb]);
}

// ------------------------------------------------------------------
// OpSpec::parse error paths (operator grammar + dependency rules)
// ------------------------------------------------------------------

#[test]
fn op_spec_rejects_malformed_operators() {
    use piper::ops::OpSpec;
    // unknown operator names, with and without arguments
    assert!(OpSpec::parse("frobnicate").is_err());
    assert!(OpSpec::parse("frobnicate:7").is_err());
    // arguments on argument-less operators
    for op in ["decode", "fillmissing", "hex2int", "genvocab", "applyvocab",
               "neg2zero", "logarithm", "concatenate"] {
        assert!(OpSpec::parse(&format!("{op}:3")).is_err(), "{op} takes no arg");
    }
    // modulus argument validation: missing, non-numeric, zero, negative,
    // overflow
    assert!(OpSpec::parse("modulus").is_err());
    assert!(OpSpec::parse("modulus:abc").is_err());
    assert!(OpSpec::parse("modulus:0").is_err());
    assert!(OpSpec::parse("modulus:-5").is_err());
    assert!(OpSpec::parse("modulus:99999999999999999999").is_err());
    // well-formed forms still parse (case/whitespace-insensitive, aliases)
    assert_eq!(OpSpec::parse("  MODULUS:5_000 ").unwrap(), OpSpec::Modulus(5000));
    assert_eq!(OpSpec::parse("log").unwrap(), OpSpec::Logarithm);
    assert_eq!(OpSpec::parse("concat").unwrap(), OpSpec::Concatenate);
}

#[test]
fn pipeline_spec_dependency_rules() {
    use piper::ops::PipelineSpec;
    // GenVocab requires a preceding Modulus
    assert!(PipelineSpec::parse("genvocab").is_err());
    assert!(PipelineSpec::parse("genvocab|modulus:5").is_err(), "wrong order");
    // ApplyVocab requires a preceding GenVocab
    assert!(PipelineSpec::parse("modulus:5|applyvocab").is_err());
    assert!(PipelineSpec::parse("applyvocab|modulus:5|genvocab").is_err());
    // Neg2Zero must precede Logarithm when both are present
    assert!(PipelineSpec::parse("logarithm|neg2zero").is_err());
    // stateful operators may appear at most once
    assert!(PipelineSpec::parse("modulus:5|genvocab|genvocab").is_err());
    assert!(PipelineSpec::parse("modulus:5|genvocab|applyvocab|applyvocab").is_err());
    // empty and comma-separated specs
    assert!(PipelineSpec::parse("").is_err());
    assert!(PipelineSpec::parse(" | , ").is_err());
    assert!(PipelineSpec::parse("modulus:5,genvocab,applyvocab").is_ok());
}

#[test]
fn pipeline_spec_selector_grammar_edges() {
    use piper::ops::PipelineSpec;
    // the rules apply per column — a rule violating the dependency
    // rules fails even when another rule would satisfy them globally
    assert!(PipelineSpec::parse(
        "sparse[0]: modulus:5|genvocab; sparse[1]: applyvocab"
    )
    .is_err());
    // kind mismatches
    assert!(PipelineSpec::parse("sparse[*]: clip:0:1").is_err());
    assert!(PipelineSpec::parse("dense[*]: genvocab").is_err());
    // malformed selectors
    assert!(PipelineSpec::parse("sparse[]: modulus:5").is_err());
    assert!(PipelineSpec::parse("sparse[1..]: modulus:5").is_err());
    assert!(PipelineSpec::parse("sparse[-1]: modulus:5").is_err());
    // a trailing semicolon is tolerated
    assert!(PipelineSpec::parse("sparse[*]: modulus:5|genvocab|applyvocab;").is_ok());
    // clip/bucketize argument grammar (`:`-separated, commas stay op
    // separators)
    assert!(PipelineSpec::parse("dense[*]: clip:0:10,bucketize:1:5").is_ok());
    assert!(PipelineSpec::parse("dense[*]: clip:10:0").is_err());
    assert!(PipelineSpec::parse("dense[*]: bucketize:5:5").is_err());
}

// ------------------------------------------------------------------
// partition_rows edge cases (row-partitioned threading)
// ------------------------------------------------------------------

#[test]
fn partition_rows_zero_rows_yields_empty_ranges() {
    use piper::cpu_baseline::pipeline::partition_rows;
    let parts = partition_rows(0, 5);
    assert_eq!(parts.len(), 5);
    assert!(parts.iter().all(|r| r.is_empty()));
    // zero threads is clamped to one
    let parts = partition_rows(0, 0);
    assert_eq!(parts.len(), 1);
    assert!(parts[0].is_empty());
}

#[test]
fn partition_rows_more_threads_than_rows() {
    use piper::cpu_baseline::pipeline::partition_rows;
    let parts = partition_rows(3, 8);
    assert_eq!(parts.len(), 8);
    let total: usize = parts.iter().map(|r| r.len()).sum();
    assert_eq!(total, 3, "every row lands exactly once");
    // the first `rows` threads get one row each, the rest are empty
    assert!(parts[..3].iter().all(|r| r.len() == 1));
    assert!(parts[3..].iter().all(|r| r.is_empty()));
    // contiguous and ordered
    for w in parts.windows(2) {
        assert_eq!(w[0].end, w[1].start);
    }
}

#[test]
fn partition_rows_remainder_spread_evenly() {
    use piper::cpu_baseline::pipeline::partition_rows;
    let parts = partition_rows(10, 4); // 3,3,2,2
    let lens: Vec<usize> = parts.iter().map(|r| r.len()).collect();
    assert_eq!(lens, vec![3, 3, 2, 2]);
    assert_eq!(parts[0].start, 0);
    assert_eq!(parts.last().map(|r| r.end), Some(10));
}
