//! Artifact round-trip suite: the frozen vocabulary file format must be
//! bit-stable across vocabulary backends and must reject every kind of
//! damage — corruption, truncation, version skew, and spec/schema
//! mismatch — at load time, never at serving time.

use piper::data::Schema;
use piper::ops::artifact::{fnv1a, VocabArtifact};
use piper::ops::{DirectVocab, HashVocab, PipelineSpec, Vocab};

/// An observation stream with repeats and an out-of-order tail.
const STREAM: [u32; 8] = [42, 7, 42, 0, 99, 7, 3, 99];

fn sample_spec() -> PipelineSpec {
    PipelineSpec::dlrm(997)
}

fn sample_artifact() -> VocabArtifact {
    VocabArtifact::new(
        sample_spec(),
        Schema::new(2, 3),
        vec![vec![42, 7, 0, 99, 3], vec![], vec![5, 1]],
    )
    .expect("sample artifact")
}

/// Patch `buf` in place and restore the trailing checksum, so decode
/// exercises the *semantic* validation behind the checksum, not the
/// checksum itself.
fn patch_and_refix(buf: &mut [u8], at: usize, bytes: &[u8]) {
    buf[at..at + bytes.len()].copy_from_slice(bytes);
    let body_end = buf.len() - 8;
    let sum = fnv1a(&buf[..body_end]).to_le_bytes();
    buf[body_end..].copy_from_slice(&sum);
}

#[test]
fn direct_and_hash_backends_freeze_to_identical_bytes() {
    // Same observation stream through both GenVocab backends — the
    // artifact must not remember which backend built it.
    let mut direct = DirectVocab::new(128);
    let mut hash = HashVocab::new();
    for &v in &STREAM {
        direct.observe(v);
        hash.observe(v);
    }
    assert_eq!(direct.export_keys(), hash.export_keys());

    let schema = Schema::new(1, 1);
    let a = VocabArtifact::new(sample_spec(), schema, vec![direct.export_keys()]).unwrap();
    let b = VocabArtifact::new(sample_spec(), schema, vec![hash.export_keys()]).unwrap();
    assert_eq!(a.encode(), b.encode(), "backend choice must not leak into the artifact");
}

#[test]
fn save_load_is_bit_identical() {
    let artifact = sample_artifact();
    let path = std::env::temp_dir()
        .join(format!("piper-artifact-roundtrip-{}.bin", std::process::id()));
    artifact.save(&path).expect("save");
    let loaded = VocabArtifact::load(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, artifact);
    assert_eq!(loaded.encode(), artifact.encode(), "re-encode must be bit-identical");
    assert_eq!(loaded.spec_hash(), artifact.spec_hash());
    assert_eq!(loaded.schema_hash(), artifact.schema_hash());
}

#[test]
fn corrupted_byte_is_rejected() {
    let good = sample_artifact().encode();
    // Flip one byte in a vocabulary entry (past the header), leaving
    // the checksum alone: the trailing FNV must catch it.
    let mut bad = good.clone();
    let mid = good.len() / 2;
    bad[mid] ^= 0x01;
    assert!(VocabArtifact::decode(&bad).is_err(), "checksum must catch a flipped byte");
}

#[test]
fn wrong_version_is_rejected() {
    let mut buf = sample_artifact().encode();
    // Version lives at bytes 4..6; refix the checksum so the version
    // check itself must fire.
    patch_and_refix(&mut buf, 4, &99u16.to_le_bytes());
    let err = VocabArtifact::decode(&buf).expect_err("version 99 must be rejected");
    assert!(err.to_string().contains("version"), "unhelpful error: {err:#}");
}

#[test]
fn truncated_file_is_rejected() {
    let artifact = sample_artifact();
    let good = artifact.encode();
    let path = std::env::temp_dir()
        .join(format!("piper-artifact-truncated-{}.bin", std::process::id()));
    for cut in [0, 1, 10, good.len() / 2, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).expect("write truncated");
        assert!(
            VocabArtifact::load(&path).is_err(),
            "a file truncated to {cut} bytes must be rejected"
        );
    }
    // Sanity: the untruncated file still loads.
    std::fs::write(&path, &good).expect("write full");
    assert_eq!(VocabArtifact::load(&path).expect("full file loads"), artifact);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tampered_spec_hash_is_rejected() {
    let mut buf = sample_artifact().encode();
    // Stored spec hash lives at bytes 14..22.
    patch_and_refix(&mut buf, 14, &0xdead_beef_dead_beefu64.to_le_bytes());
    let err = VocabArtifact::decode(&buf).expect_err("spec hash mismatch must be rejected");
    assert!(err.to_string().contains("spec"), "unhelpful error: {err:#}");
}

#[test]
fn tampered_schema_is_rejected() {
    let mut buf = sample_artifact().encode();
    // num_sparse lives at bytes 10..14; growing it breaks both the
    // stored schema hash and the column count — either way, rejected.
    patch_and_refix(&mut buf, 10, &4u32.to_le_bytes());
    assert!(VocabArtifact::decode(&buf).is_err(), "schema tamper must be rejected");
}

#[test]
fn missing_file_is_a_clean_error() {
    let path = std::env::temp_dir()
        .join(format!("piper-artifact-missing-{}.bin", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let err = VocabArtifact::load(&path).expect_err("missing file");
    assert!(
        err.to_string().contains("artifact"),
        "the error should say what failed to load: {err:#}"
    );
}
