//! Hostile-input suite for row-level error containment: every backend,
//! both strategies, both wire formats and both decode-thread settings
//! must make the same keep/skip/quarantine decision for every defective
//! row — and the kept rows must come out bit-identical to a run over
//! the pre-cleaned input. Also pins the budget abort, the typed
//! `on_error=fail` error, the quarantine side file's replayability and
//! the merged containment counters of a two-worker cluster.

use piper::accel::{InputFormat, Mode};
use piper::coordinator::Backend;
use piper::cpu_baseline::ConfigKind;
use piper::data::row::ProcessedColumns;
use piper::data::{binary, utf8, Schema, SynthConfig, SynthDataset};
use piper::decode::{DataError, ErrorBudget, ErrorPolicy, RowErrorKind};
use piper::net::protocol::Job;
use piper::net::run_cluster_loopback;
use piper::net::stream::WireFormat;
use piper::ops::{Modulus, PipelineSpec};
use piper::pipeline::{
    ExecStrategy, MemorySource, Pipeline, PipelineBuilder, QuarantineFile, QuarantineSource,
    RunReport,
};

const ROWS: usize = 400;
const VOCAB: u32 = 997;
/// Dirty-stream row indices of the four injected defects, in order:
/// illegal byte, wrong field count, numeric overflow, oversized field.
const BAD_ROWS: [u64; 4] = [3, 10, 57, 200];
const BAD_KINDS: [RowErrorKind; 4] = [
    RowErrorKind::IllegalByte,
    RowErrorKind::WrongFieldCount,
    RowErrorKind::NumericOverflow,
    RowErrorKind::OversizedField,
];

fn dataset() -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(ROWS))
}

/// The clean encoding, the dirty encoding (four malformed rows injected
/// at [`BAD_ROWS`]), the injected lines and their stream-absolute
/// offsets in the dirty stream. Every defect sits at its row's first
/// byte, so expected error offsets == expected row starts.
struct DirtyUtf8 {
    clean: Vec<u8>,
    dirty: Vec<u8>,
    bad_lines: Vec<Vec<u8>>,
    bad_offsets: Vec<u64>,
}

fn dirty_utf8(ds: &SynthDataset) -> DirtyUtf8 {
    let clean = utf8::encode_dataset(ds);
    let mut lines: Vec<Vec<u8>> = clean
        .split_inclusive(|&b| b == b'\n')
        .map(|l| l.to_vec())
        .collect();
    assert_eq!(lines.len(), ROWS);

    let template = |i: usize| lines[i].clone();
    // Illegal byte: corrupt the first label digit.
    let mut bad_illegal = template(0);
    bad_illegal[0] = b'Z';
    // Wrong field count: drop the last field (truncate at the last tab).
    let src = template(1);
    let last_tab = src.iter().rposition(|&b| b == b'\t').unwrap();
    let mut bad_short = src[..last_tab].to_vec();
    bad_short.push(b'\n');
    // Numeric overflow: a label past u32::MAX.
    let src = template(2);
    let first_tab = src.iter().position(|&b| b == b'\t').unwrap();
    let mut bad_overflow = b"99999999999".to_vec();
    bad_overflow.extend_from_slice(&src[first_tab..]);
    // Oversized field: a 70-digit label (oversized outranks overflow).
    let src = template(3);
    let first_tab = src.iter().position(|&b| b == b'\t').unwrap();
    let mut bad_oversized = vec![b'9'; 70];
    bad_oversized.extend_from_slice(&src[first_tab..]);

    let bad_lines =
        vec![bad_illegal, bad_short, bad_overflow, bad_oversized];
    for (i, line) in bad_lines.iter().enumerate() {
        // Ascending insert positions never shift earlier inserts.
        lines.insert(BAD_ROWS[i] as usize, line.clone());
    }

    let mut dirty = Vec::new();
    let mut starts = Vec::new();
    for line in &lines {
        starts.push(dirty.len() as u64);
        dirty.extend_from_slice(line);
    }
    let bad_offsets = BAD_ROWS.iter().map(|&r| starts[r as usize]).collect();
    DirtyUtf8 { clean, dirty, bad_lines, bad_offsets }
}

fn build(
    backend: &Backend,
    input: InputFormat,
    strategy: ExecStrategy,
    threads: usize,
    policy: Option<ErrorPolicy>,
) -> Pipeline {
    let mut b = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(Schema::CRITEO)
        .input(input)
        .chunk_rows(64)
        .strategy(strategy)
        .decode_threads(threads)
        .executor(backend.executor());
    if let Some(p) = policy {
        b = b.on_error(p);
    }
    b.build().expect("planning must succeed")
}

fn run(pipeline: &Pipeline, raw: &[u8], input: InputFormat) -> (ProcessedColumns, RunReport) {
    let mut src = MemorySource::new(raw, input);
    pipeline.run_collect(&mut src).expect("run must succeed")
}

fn assert_contained(report: &RunReport, ctx: &str) {
    assert_eq!(report.rows, ROWS, "{ctx}: kept rows");
    assert_eq!(report.row_errors.total, 4, "{ctx}: defect total");
    let got: Vec<(u64, RowErrorKind, u64)> =
        report.row_errors.recorded.iter().map(|e| (e.offset, e.kind, e.row)).collect();
    let want: Vec<(u64, RowErrorKind, u64)> = (0..4)
        .map(|i| (dirty_fixture().bad_offsets[i], BAD_KINDS[i], BAD_ROWS[i]))
        .collect();
    assert_eq!(got, want, "{ctx}: defect details");
    for kind in BAD_KINDS {
        assert_eq!(
            report.row_errors.by_kind[kind.as_u8() as usize],
            1,
            "{ctx}: one {kind} defect"
        );
    }
}

/// The fixture is deterministic (seeded synth), so building it per call
/// keeps the helpers free of lifetimes without changing the data.
fn dirty_fixture() -> DirtyUtf8 {
    dirty_utf8(&dataset())
}

fn utf8_backends() -> Vec<Backend> {
    vec![
        Backend::Cpu { kind: ConfigKind::I, threads: 2 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ]
}

#[test]
fn skip_matches_precleaned_input_across_the_matrix() {
    let fx = dirty_fixture();
    for backend in utf8_backends() {
        for strategy in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
            for threads in [1usize, 4] {
                let ctx = format!("{}/{:?}/t{threads}", backend.name(), strategy);
                let clean_pipe =
                    build(&backend, InputFormat::Utf8, strategy, threads, None);
                let (reference, clean_report) =
                    run(&clean_pipe, &fx.clean, InputFormat::Utf8);
                assert_eq!(clean_report.rows, ROWS, "{ctx}: clean rows");
                assert_eq!(clean_report.row_errors.total, 0, "{ctx}: clean defects");

                let skip_pipe = build(
                    &backend,
                    InputFormat::Utf8,
                    strategy,
                    threads,
                    Some(ErrorPolicy::Skip),
                );
                let (cols, report) = run(&skip_pipe, &fx.dirty, InputFormat::Utf8);
                assert_eq!(cols, reference, "{ctx}: dirty+skip == clean output");
                assert_contained(&report, &ctx);
                assert_eq!(report.rows_skipped, 4, "{ctx}: skipped");
                assert_eq!(report.rows_quarantined, 0, "{ctx}: quarantined");
            }
        }
    }
}

#[test]
fn binary_truncated_tail_is_skippable_across_backends() {
    let ds = dataset();
    let clean = binary::encode_dataset(&ds);
    let mut dirty = clean.clone();
    dirty.extend_from_slice(&[0xAB, 0xCD, 0xEF]); // 3 stray tail bytes

    for backend in [
        Backend::Cpu { kind: ConfigKind::III, threads: 2 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ] {
        for strategy in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
            let ctx = format!("{}/{:?}", backend.name(), strategy);
            let clean_pipe = build(&backend, InputFormat::Binary, strategy, 1, None);
            let (reference, _) = run(&clean_pipe, &clean, InputFormat::Binary);

            // The legacy zero policy keeps rejecting the whole stream.
            let zero_pipe = build(&backend, InputFormat::Binary, strategy, 1, None);
            let mut src = MemorySource::new(&dirty, InputFormat::Binary);
            let err = zero_pipe.run_collect(&mut src).expect_err("zero must reject");
            assert!(
                format!("{err:#}").contains("stray bytes"),
                "{ctx}: legacy message must survive: {err:#}"
            );

            let skip_pipe = build(
                &backend,
                InputFormat::Binary,
                strategy,
                1,
                Some(ErrorPolicy::Skip),
            );
            let (cols, report) = run(&skip_pipe, &dirty, InputFormat::Binary);
            assert_eq!(cols, reference, "{ctx}: kept rows bit-identical");
            assert_eq!(report.rows, ROWS, "{ctx}: rows");
            assert_eq!(report.rows_skipped, 1, "{ctx}: the truncated tail row");
            let first = report.row_errors.first().expect("one defect");
            assert_eq!(first.kind, RowErrorKind::WrongFieldCount, "{ctx}");
            assert_eq!(first.offset, clean.len() as u64, "{ctx}: tail offset");
        }
    }
}

#[test]
fn quarantine_writes_a_replayable_side_file() {
    let fx = dirty_fixture();
    let qpath = std::env::temp_dir()
        .join(format!("piper-dirty-qrn-{}.bin", std::process::id()));

    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(Schema::CRITEO)
        .input(InputFormat::Utf8)
        .chunk_rows(64)
        .strategy(ExecStrategy::Fused)
        .executor(Backend::Piper { mode: Mode::Network }.executor())
        .quarantine(&qpath) // implies on_error=quarantine
        .build()
        .unwrap();
    let (cols, report) = run(&pipeline, &fx.dirty, InputFormat::Utf8);

    let clean_pipe = build(
        &Backend::Piper { mode: Mode::Network },
        InputFormat::Utf8,
        ExecStrategy::Fused,
        piper::decode::shard::default_threads(),
        None,
    );
    let (reference, _) = run(&clean_pipe, &fx.clean, InputFormat::Utf8);
    assert_eq!(cols, reference, "dirty+quarantine == clean output");
    assert_eq!(report.rows_quarantined, 4);
    assert_eq!(report.rows_skipped, 0);
    assert_eq!(report.quarantine.rows, 4);
    assert_eq!(report.quarantine.path.as_deref(), Some(qpath.as_path()));

    // The side file holds the rows verbatim with exact provenance.
    let file = QuarantineFile::load(&qpath).unwrap();
    assert_eq!(file.format, InputFormat::Utf8);
    let got: Vec<(u64, u64, RowErrorKind, &[u8])> =
        file.rows.iter().map(|r| (r.row, r.offset, r.kind, r.bytes.as_slice())).collect();
    let want: Vec<(u64, u64, RowErrorKind, &[u8])> = (0..4)
        .map(|i| (BAD_ROWS[i], fx.bad_offsets[i], BAD_KINDS[i], fx.bad_lines[i].as_slice()))
        .collect();
    assert_eq!(got, want, "quarantine records");

    // Replay: the same defects are re-detected from the side file.
    let mut src = QuarantineSource::open(&qpath).unwrap();
    let replay_pipe = build(
        &Backend::Cpu { kind: ConfigKind::I, threads: 2 },
        InputFormat::Utf8,
        ExecStrategy::Fused,
        1,
        Some(ErrorPolicy::Skip),
    );
    let (_, replay) = replay_pipe.run_collect(&mut src).unwrap();
    assert_eq!(replay.rows, 0, "every quarantined row is still defective");
    assert_eq!(replay.rows_skipped, 4);
    let kinds: Vec<RowErrorKind> =
        replay.row_errors.recorded.iter().map(|e| e.kind).collect();
    assert_eq!(kinds, BAD_KINDS.to_vec(), "defect kinds survive the round trip");

    let _ = std::fs::remove_file(&qpath);
}

#[test]
fn fail_aborts_with_a_typed_error_naming_the_first_offset() {
    let fx = dirty_fixture();
    for strategy in [ExecStrategy::Fused, ExecStrategy::TwoPass] {
        let pipeline = build(
            &Backend::Cpu { kind: ConfigKind::I, threads: 2 },
            InputFormat::Utf8,
            strategy,
            2,
            Some(ErrorPolicy::Fail),
        );
        let mut src = MemorySource::new(&fx.dirty, InputFormat::Utf8);
        let err = pipeline.run_collect(&mut src).expect_err("fail must abort");
        match DataError::of(&err) {
            Some(DataError::Row(e)) => {
                assert_eq!(e.kind, RowErrorKind::IllegalByte, "{strategy:?}");
                assert_eq!(e.offset, fx.bad_offsets[0], "{strategy:?}: first offset");
                assert_eq!(e.row, BAD_ROWS[0], "{strategy:?}: first row");
            }
            other => panic!("{strategy:?}: expected DataError::Row, got {other:?} / {err:#}"),
        }
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&fx.bad_offsets[0].to_string()),
            "{strategy:?}: message must name the offending offset: {msg}"
        );
    }
}

#[test]
fn error_budgets_abort_with_a_typed_error() {
    let fx = dirty_fixture();
    // Absolute count: 4 defects against a budget of 3.
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(Schema::CRITEO)
        .input(InputFormat::Utf8)
        .chunk_rows(64)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .on_error(ErrorPolicy::Skip)
        .error_budget(ErrorBudget::Count(3))
        .build()
        .unwrap();
    let mut src = MemorySource::new(&fx.dirty, InputFormat::Utf8);
    let err = pipeline.run_collect(&mut src).expect_err("budget must abort");
    match DataError::of(&err) {
        Some(DataError::BudgetExceeded { errors, budget, first, .. }) => {
            assert_eq!(*errors, 4);
            assert_eq!(*budget, ErrorBudget::Count(3));
            assert_eq!(first.expect("detail survives").offset, fx.bad_offsets[0]);
        }
        other => panic!("expected BudgetExceeded, got {other:?} / {err:#}"),
    }

    // Rate budget: ~1% defective against a 0.5% allowance.
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(Schema::CRITEO)
        .input(InputFormat::Utf8)
        .chunk_rows(64)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .on_error(ErrorPolicy::Skip)
        .error_budget(ErrorBudget::Rate(0.005))
        .build()
        .unwrap();
    let mut src = MemorySource::new(&fx.dirty, InputFormat::Utf8);
    let err = pipeline.run_collect(&mut src).expect_err("rate budget must abort");
    assert!(
        matches!(DataError::of(&err), Some(DataError::BudgetExceeded { .. })),
        "typed rate abort: {err:#}"
    );

    // A generous budget lets the same run complete.
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(Schema::CRITEO)
        .input(InputFormat::Utf8)
        .chunk_rows(64)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .on_error(ErrorPolicy::Skip)
        .error_budget(ErrorBudget::Count(4))
        .build()
        .unwrap();
    let mut src = MemorySource::new(&fx.dirty, InputFormat::Utf8);
    let (_, report) = pipeline.run_collect(&mut src).unwrap();
    assert_eq!(report.rows_skipped, 4);
}

#[test]
fn two_worker_cluster_merges_exact_containment_counters() {
    let fx = dirty_fixture();
    let spec = PipelineSpec::dlrm(VOCAB);

    let clean_job = Job {
        schema: Schema::CRITEO,
        spec: spec.clone(),
        format: WireFormat::Utf8,
        errors: Default::default(),
    };
    let reference = run_cluster_loopback(2, &clean_job, &fx.clean, 619).unwrap();
    assert_eq!(reference.stats.rows, ROWS as u64);
    assert_eq!(reference.stats.rows_skipped + reference.stats.rows_quarantined, 0);

    let mut skip_job = clean_job.clone();
    skip_job.errors.policy = ErrorPolicy::Skip;
    let run = run_cluster_loopback(2, &skip_job, &fx.dirty, 619).unwrap();
    assert_eq!(run.processed, reference.processed, "dirty+skip == clean output");
    assert_eq!(run.stats.rows, ROWS as u64);
    assert_eq!(run.stats.rows_skipped, 4, "merged across workers");
    assert_eq!(run.stats.rows_quarantined, 0);
    assert!(run.stats.illegal_bytes >= 1, "the corrupted label byte");

    // Quarantine over the wire contains like skip but attributes the
    // counter to the requested policy (raw bytes stay worker-local).
    let mut q_job = clean_job.clone();
    q_job.errors.policy = ErrorPolicy::Quarantine;
    let run = run_cluster_loopback(2, &q_job, &fx.dirty, 619).unwrap();
    assert_eq!(run.processed, reference.processed);
    assert_eq!(run.stats.rows_quarantined, 4);
    assert_eq!(run.stats.rows_skipped, 0);

    // A per-job budget aborts the whole cluster run with a job failure.
    let mut tight_job = skip_job.clone();
    tight_job.errors.budget = ErrorBudget::Count(1);
    assert!(run_cluster_loopback(2, &tight_job, &fx.dirty, 619).is_err());
}
