//! The load-bearing acceptance suite of the execution-strategy
//! refactor: for every executor (CPU baseline, GPU model, all three
//! PIPER modes), every source kind and both input formats, the fused
//! single-pass strategy must produce output **bit-identical** to the
//! two-pass strategy — and must really run in one decode pass with zero
//! source rewinds.
//!
//! CI runs this suite under `--release` so the fused hot path is
//! exercised optimized.

use piper::accel::{InputFormat, Mode};
use piper::coordinator::Backend;
use piper::cpu_baseline::ConfigKind;
use piper::data::row::ProcessedColumns;
use piper::data::{binary, synth::SynthConfig, utf8, SynthDataset};
use piper::ops::PipelineSpec;
use piper::pipeline::{
    CountSink, ExecStrategy, FileSource, MemorySource, Pipeline, PipelineBuilder, ReaderSource,
    Source, SynthSource,
};

const ROWS: usize = 350;
const VOCAB: u32 = 997;

fn dataset() -> SynthDataset {
    SynthDataset::generate(SynthConfig::small(ROWS))
}

fn build(backend: &Backend, input: InputFormat, strategy: ExecStrategy) -> Pipeline {
    PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(VOCAB))
        .schema(dataset().schema())
        .input(input)
        .chunk_rows(64)
        .strategy(strategy)
        .executor(backend.executor())
        .build()
        .expect("planning must succeed")
}

/// Every backend of the comparison, including all three PIPER modes.
fn all_backends(input: InputFormat) -> Vec<Backend> {
    let cpu_kind = match input {
        InputFormat::Utf8 => ConfigKind::I,
        InputFormat::Binary => ConfigKind::III,
    };
    vec![
        Backend::Cpu { kind: cpu_kind, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::LocalDecodeInKernel },
        Backend::Piper { mode: Mode::LocalDecodeInHost },
        Backend::Piper { mode: Mode::Network },
    ]
}

/// Source wrapper counting rewinds — the "zero rewinds in fused mode"
/// regression pin.
struct ResetMeter<S: Source> {
    inner: S,
    resets: usize,
}

impl<S: Source> Source for ResetMeter<S> {
    fn format(&self) -> InputFormat {
        self.inner.format()
    }
    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> piper::Result<bool> {
        self.inner.next_chunk(max_bytes, buf)
    }
    fn can_rewind(&self) -> bool {
        self.inner.can_rewind()
    }
    fn reset(&mut self) -> piper::Result<()> {
        self.resets += 1;
        self.inner.reset()
    }
}

/// Seeded adversarial source: chunk sizes jump pseudorandomly between a
/// single byte and the full requested budget, so successive chunks
/// decode at wildly different speeds — tiny dribbles race through the
/// stage pipeline while a full chunk is still decoding behind them.
/// Reordering stress for the scheduler's ordering locks; deterministic
/// per seed, and rewindable so two-pass can run the same stream.
struct JitterSource<'a> {
    raw: &'a [u8],
    pos: usize,
    format: InputFormat,
    seed: u64,
    state: u64,
}

impl<'a> JitterSource<'a> {
    fn new(raw: &'a [u8], format: InputFormat, seed: u64) -> Self {
        JitterSource { raw, pos: 0, format, seed, state: seed }
    }
}

impl Source for JitterSource<'_> {
    fn format(&self) -> InputFormat {
        self.format
    }
    fn next_chunk(&mut self, max_bytes: usize, buf: &mut Vec<u8>) -> piper::Result<bool> {
        buf.clear();
        if self.pos >= self.raw.len() {
            return Ok(false);
        }
        // LCG step (Knuth MMIX constants); high bits decide the size.
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let take = (1 + (self.state >> 33) as usize % max_bytes.max(1))
            .min(self.raw.len() - self.pos);
        buf.extend_from_slice(&self.raw[self.pos..self.pos + take]);
        self.pos += take;
        Ok(true)
    }
    fn can_rewind(&self) -> bool {
        true
    }
    fn reset(&mut self) -> piper::Result<()> {
        self.pos = 0;
        self.state = self.seed;
        Ok(())
    }
}

/// The stage-pipelined scheduler's acceptance pin: for every executor ×
/// format, and for `pipeline_depth ∈ {1, 2, 4}`, fused output is
/// bit-identical to the two-pass reference — over a well-behaved memory
/// source and over the adversarial jitter source whose chunk sizes (and
/// therefore decode times) swing wildly.
#[test]
fn pipelined_depths_bit_identical_across_executors_sources_formats() {
    let ds = dataset();
    for input in [InputFormat::Utf8, InputFormat::Binary] {
        let raw = match input {
            InputFormat::Utf8 => utf8::encode_dataset(&ds),
            InputFormat::Binary => binary::encode_dataset(&ds),
        };
        for backend in all_backends(input) {
            let mut src = MemorySource::new(&raw, input);
            let (want, _) = build(&backend, input, ExecStrategy::TwoPass)
                .run_collect(&mut src)
                .unwrap();

            for depth in [1usize, 2, 4] {
                let pipeline = PipelineBuilder::new()
                    .spec(PipelineSpec::dlrm(VOCAB))
                    .schema(ds.schema())
                    .input(input)
                    .chunk_rows(64)
                    .strategy(ExecStrategy::Fused)
                    .pipeline_depth(depth)
                    .executor(backend.executor())
                    .build()
                    .unwrap();

                let mut src = MemorySource::new(&raw, input);
                let (cols, report) = pipeline.run_collect(&mut src).unwrap();
                assert_eq!(
                    cols,
                    want,
                    "{} {input:?} depth {depth}: pipelined fused must match two-pass",
                    backend.name()
                );
                assert_eq!(report.decode_passes, 1);
                assert_eq!(
                    report.pipeline_depth, depth,
                    "{} {input:?}: effective depth must be reported",
                    backend.name()
                );

                // The same pipeline over the adversarial stream: chunk
                // boundaries move, decode speeds swing, output must not.
                let mut jit = JitterSource::new(&raw, input, 0xC0FFEE ^ depth as u64);
                let (jit_cols, jit_report) = pipeline.run_collect(&mut jit).unwrap();
                assert_eq!(
                    jit_cols,
                    want,
                    "{} {input:?} depth {depth} / jitter source",
                    backend.name()
                );
                assert_eq!(jit_report.rows, ROWS);
                assert!(jit_report.chunks >= report.chunks, "jitter must fragment the stream");
            }
        }
    }
}

/// The refactor's core guarantee: fused == two-pass, bit for bit, for
/// every executor × format × source kind.
#[test]
fn fused_equals_two_pass_all_executors_sources_formats() {
    let ds = dataset();
    for input in [InputFormat::Utf8, InputFormat::Binary] {
        let raw = match input {
            InputFormat::Utf8 => utf8::encode_dataset(&ds),
            InputFormat::Binary => binary::encode_dataset(&ds),
        };
        let file = std::env::temp_dir().join(format!(
            "piper-fused-eq-{}-{input:?}.dat",
            std::process::id()
        ));
        std::fs::write(&file, &raw).unwrap();

        for backend in all_backends(input) {
            let fused = build(&backend, input, ExecStrategy::Fused);
            let two_pass = build(&backend, input, ExecStrategy::TwoPass);

            // Memory source (the reference run).
            let mut src = MemorySource::new(&raw, input);
            let (two_cols, two_report) = two_pass.run_collect(&mut src).unwrap();
            let mut src = MemorySource::new(&raw, input);
            let (fused_cols, fused_report) = fused.run_collect(&mut src).unwrap();
            assert_eq!(
                fused_cols, two_cols,
                "{} {input:?}: fused output must be bit-identical to two-pass",
                backend.name()
            );
            assert_eq!(fused_report.strategy, ExecStrategy::Fused);
            assert_eq!(two_report.strategy, ExecStrategy::TwoPass);
            assert_eq!(fused_report.decode_passes, 1, "{}", backend.name());
            assert_eq!(two_report.decode_passes, 2, "{}", backend.name());
            assert_eq!(fused_report.vocab_entries, two_report.vocab_entries);
            assert_eq!(fused_report.rows, ROWS);

            // File source through the same fused pipeline.
            let mut fsrc = FileSource::open(&file, input).unwrap();
            let (file_cols, _) = fused.run_collect(&mut fsrc).unwrap();
            assert_eq!(file_cols, two_cols, "{} {input:?} / file", backend.name());

            // Generator source — nothing materialized anywhere.
            let mut synth = SynthSource::new(SynthConfig::small(ROWS), input);
            let (synth_cols, _) = fused.run_collect(&mut synth).unwrap();
            assert_eq!(synth_cols, two_cols, "{} {input:?} / synth", backend.name());
        }
        std::fs::remove_file(&file).ok();
    }
}

/// Regression pin: a fused `gen_vocab` run performs exactly one decode
/// pass and never calls `Source::reset`; the two-pass run rewinds once.
#[test]
fn fused_mode_never_rewinds() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    for (strategy, want_resets, want_passes) in
        [(ExecStrategy::Fused, 0usize, 1usize), (ExecStrategy::TwoPass, 1, 2)]
    {
        let pipeline =
            build(&Backend::Cpu { kind: ConfigKind::I, threads: 2 }, InputFormat::Utf8, strategy);
        let mut src = ResetMeter { inner: MemorySource::new(&raw, InputFormat::Utf8), resets: 0 };
        let mut sink = CountSink::new();
        let report = pipeline.run(&mut src, &mut sink).unwrap();
        assert_eq!(src.resets, want_resets, "{strategy:?}");
        assert_eq!(report.decode_passes, want_passes, "{strategy:?}");
        assert_eq!(sink.rows, ROWS);
    }
}

/// The builder defaults to fused for every backend that supports it —
/// which is all of them.
#[test]
fn builder_defaults_to_fused_for_all_backends() {
    for backend in all_backends(InputFormat::Utf8) {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(VOCAB))
            .schema(dataset().schema())
            .input(InputFormat::Utf8)
            .executor(backend.executor())
            .build()
            .unwrap();
        assert_eq!(
            pipeline.plan().strategy,
            ExecStrategy::Fused,
            "{} should plan fused by default",
            backend.name()
        );
    }
}

/// A one-shot (non-rewindable) source is accepted by a fused `gen_vocab`
/// plan and rejected — at submission, with a clear error — by a two-pass
/// one. This is the serving posture the fused strategy unlocks: stateful
/// preprocessing over a stream that exists only once.
#[test]
fn one_shot_reader_source_requires_fused() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);

    let cpu = Backend::Cpu { kind: ConfigKind::I, threads: 2 };
    let fused = build(&cpu, InputFormat::Utf8, ExecStrategy::Fused);
    let mut src = ReaderSource::new(std::io::Cursor::new(raw.clone()), InputFormat::Utf8);
    let (cols, report) = fused.run_collect(&mut src).unwrap();
    let mut mem = MemorySource::new(&raw, InputFormat::Utf8);
    let two_pass = build(&cpu, InputFormat::Utf8, ExecStrategy::TwoPass);
    let (want, _) = two_pass.run_collect(&mut mem).unwrap();
    assert_eq!(cols, want, "fused over a one-shot reader must match");
    assert_eq!(report.decode_passes, 1);

    let mut src = ReaderSource::new(std::io::Cursor::new(raw.clone()), InputFormat::Utf8);
    let err = two_pass.run_collect(&mut src);
    assert!(err.is_err(), "two-pass over a one-shot source must fail at submission");
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("rewind"), "error should explain the rewind requirement: {msg}");
}

/// Custom operator graphs fuse too: every valid flag combination agrees
/// across strategies (including genvocab-without-applyvocab, where the
/// vocab builds but raw modulus values pass through).
#[test]
fn custom_specs_fuse_identically() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    for spec in [
        "modulus:97|genvocab|applyvocab",
        "modulus:97|genvocab",
        "modulus:97|genvocab|applyvocab|neg2zero|logarithm",
        "modulus:53|neg2zero",
    ] {
        let run = |strategy: ExecStrategy| -> ProcessedColumns {
            let pipeline = PipelineBuilder::new()
                .spec_str(spec)
                .unwrap()
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(64)
                .strategy(strategy)
                .executor(Backend::Cpu { kind: ConfigKind::I, threads: 3 }.executor())
                .build()
                .unwrap();
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            pipeline.run_collect(&mut src).unwrap().0
        };
        assert_eq!(run(ExecStrategy::Fused), run(ExecStrategy::TwoPass), "spec {spec}");
    }
}

/// Chunk size must not change fused output (the vocab state spans
/// chunks).
#[test]
fn fused_output_is_chunk_size_invariant() {
    let ds = dataset();
    let raw = utf8::encode_dataset(&ds);
    let mut reference: Option<ProcessedColumns> = None;
    for chunk_rows in [1usize, 7, 100, 1_000_000] {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(VOCAB))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(chunk_rows)
            .strategy(ExecStrategy::Fused)
            .executor(Backend::Piper { mode: Mode::Network }.executor())
            .build()
            .unwrap();
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, _) = pipeline.run_collect(&mut src).unwrap();
        let expect = reference.get_or_insert_with(|| cols.clone());
        assert_eq!(expect, &cols, "chunk_rows={chunk_rows}");
    }
}
