//! Decode equivalence suite: the SWAR wide-word loop, the row-sharded
//! parallel decoder and the `ParallelDecoder` fast path must be
//! bit-identical to the byte-at-a-time scalar oracle — rows,
//! fill-missing zeros *and* illegal-byte positions — across widths,
//! shard counts and chunk boundaries that split rows mid-field. The
//! accelerator's modeled cycle counts must be untouched by any software
//! speedup. CI runs this under `--release` as well: the SWAR bit tricks
//! must hold with optimizations on, not just in the debug profile.

use piper::accel::InputFormat;
use piper::data::{utf8, RowBlock, Schema, SynthConfig, SynthDataset};
use piper::decode::{
    DecodeTally, ErrorConfig, ErrorPolicy, ParallelDecoder, RowErrorKind, ScalarDecoder,
    ShardedUtf8Decoder,
};
use piper::pipeline::{ChunkDecoder, DecodeOptions};
use piper::util::XorShift64;

const WIDTHS: [usize; 4] = [1, 2, 4, 8];
const THREADS: [usize; 4] = [1, 2, 3, 8];
const CHUNKS: [usize; 5] = [1, 7, 64, 4096, usize::MAX];

/// Decode `raw` through the chunked engine front with the given decode
/// options, collecting all rows and the illegal log.
fn chunked_decode(
    schema: Schema,
    raw: &[u8],
    chunk: usize,
    opts: DecodeOptions,
) -> (Vec<piper::data::DecodedRow>, DecodeTally) {
    let mut dec = ChunkDecoder::with_options(InputFormat::Utf8, schema, opts);
    let mut out = RowBlock::new(schema);
    for c in raw.chunks(chunk.clamp(1, raw.len())) {
        dec.feed_into(c, &mut out).expect("utf8 decode is infallible");
    }
    let tally = dec.finish_into(&mut out).expect("utf8 finish is infallible");
    (out.to_rows(), tally)
}

/// Every path over one buffer: rows, error log and cycles pinned to the
/// scalar oracle.
fn assert_all_paths_match(schema: Schema, raw: &[u8], tag: &str) {
    let oracle = ScalarDecoder::new(schema).decode(raw);
    assert_eq!(oracle.cycles, raw.len() as u64, "{tag}: scalar II = 1 byte/cycle");

    for w in WIDTHS {
        let par = ParallelDecoder::with_width(schema, w).decode(raw);
        assert_eq!(par.rows, oracle.rows, "{tag}: width {w} rows");
        assert_eq!(par.illegal, oracle.illegal, "{tag}: width {w} error positions");
        assert_eq!(
            par.cycles,
            (raw.len() as u64).div_ceil(w as u64),
            "{tag}: width {w} cycles must stay the hardware model's"
        );
        let groups = ParallelDecoder::with_width(schema, w).decode_by_groups(raw);
        assert_eq!(groups.rows, oracle.rows, "{tag}: width {w} per-group rows");
        assert_eq!(groups.cycles, par.cycles, "{tag}: width {w} per-group cycles");
        assert_eq!(groups.illegal, oracle.illegal, "{tag}: width {w} per-group errors");
    }

    for threads in THREADS {
        for swar in [false, true] {
            for chunk in CHUNKS {
                let opts = DecodeOptions { threads, swar, ..Default::default() };
                let (rows, tally) = chunked_decode(schema, raw, chunk, opts);
                let ctx = format!("{tag}: threads={threads} swar={swar} chunk={chunk}");
                assert_eq!(rows, oracle.rows, "{ctx} rows");
                assert_eq!(tally.illegal, oracle.illegal, "{ctx} error positions");
            }
        }
    }
}

#[test]
fn well_formed_datasets_bit_identical() {
    for (nd, ns, rows) in [(13usize, 26usize, 600usize), (1, 1, 400), (0, 4, 300), (5, 0, 300)] {
        let mut cfg = SynthConfig::small(rows);
        cfg.schema = Schema::new(nd, ns);
        cfg.missing_rate = 0.25; // exercise FillMissing zeros heavily
        let ds = SynthDataset::generate(cfg);
        let raw = utf8::encode_dataset(&ds);
        let decoded = ScalarDecoder::new(ds.schema()).decode(&raw);
        assert_eq!(decoded.rows, ds.rows, "oracle round-trip {nd}x{ns}");
        assert!(decoded.illegal.is_empty());
        assert_all_paths_match(ds.schema(), &raw, &format!("schema {nd}x{ns}"));
    }
}

#[test]
fn random_legal_soup_bit_identical() {
    // Legal bytes only, but no row structure: fields longer than 8
    // nibbles (register wrap), empty rows, minus signs mid-field,
    // columns beyond the schema — the state machines must agree on all
    // of it, including across shard seams.
    let legal = b"\t\n-0123456789abcdef";
    let schema = Schema::new(3, 3);
    let mut rng = XorShift64::new(0x5AAB_0001);
    for case in 0..40 {
        let len = 200 + rng.below(3_000) as usize;
        let raw: Vec<u8> =
            (0..len).map(|_| legal[rng.below(legal.len() as u64) as usize]).collect();
        assert_all_paths_match(schema, &raw, &format!("legal soup case {case}"));
    }
}

#[test]
fn random_arbitrary_bytes_bit_identical_with_error_positions() {
    // Fully adversarial: all 256 byte values, so the SWAR classifier's
    // exactness (high-bit lanes, zero-test false positives) is load
    // bearing, and every path must report the same skipped offsets.
    let schema = Schema::new(2, 2);
    let mut rng = XorShift64::new(0xD15C0);
    for case in 0..40 {
        let len = 100 + rng.below(2_000) as usize;
        let mut raw: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // Sprinkle newlines so rows actually terminate now and then.
        for i in (0..raw.len()).step_by(97) {
            raw[i] = b'\n';
        }
        let oracle = ScalarDecoder::new(schema).decode(&raw);
        assert!(oracle.illegal.total > 0, "case {case} should contain illegal bytes");
        assert_all_paths_match(schema, &raw, &format!("arbitrary soup case {case}"));
    }
}

#[test]
fn sharded_error_offsets_are_chunk_absolute() {
    // Regression for the sharded path: illegal bytes at known absolute
    // offsets, decoded with chunk boundaries that split rows mid-field
    // and enough volume that chunks really shard. Offsets must be
    // reported within the original stream, never within a shard.
    let schema = Schema::new(1, 1);
    let mut raw = Vec::new();
    let mut expected = Vec::new();
    for i in 0..40_000u32 {
        let mut line = format!("{}\t{:07}\tcafef00d\n", i % 2, i).into_bytes();
        if i % 9_000 == 1_234 {
            expected.push(raw.len() as u64 + 4);
            line[4] = b'Z'; // corrupt a dense digit
        }
        raw.extend_from_slice(&line);
    }
    assert!(!expected.is_empty());
    let oracle = ScalarDecoder::new(schema).decode(&raw);
    let got_oracle: Vec<u64> = oracle.illegal.recorded.iter().map(|b| b.offset).collect();
    assert_eq!(got_oracle, expected, "oracle offsets");

    for threads in [2usize, 4, 8] {
        // One big feed (chunk interior shards) and mid-row cut feeds.
        for chunk in [usize::MAX, 1 << 20, 300_001] {
            let opts = DecodeOptions { threads, swar: true, ..Default::default() };
            let (rows, tally) = chunked_decode(schema, &raw, chunk, opts);
            assert_eq!(rows, oracle.rows, "threads={threads} chunk={chunk}");
            let got: Vec<u64> = tally.illegal.recorded.iter().map(|b| b.offset).collect();
            assert_eq!(got, expected, "threads={threads} chunk={chunk} offsets");
            assert_eq!(tally.illegal.total, expected.len() as u64);
        }
    }
}

#[test]
fn sharded_decoder_streams_like_one_shot() {
    // Drive the sharded decoder directly (not through ChunkDecoder)
    // with pathological chunk cuts; the carried row must cross every
    // boundary intact.
    let ds = SynthDataset::generate(SynthConfig::small(800));
    let raw = utf8::encode_dataset(&ds);
    let oracle = ScalarDecoder::new(ds.schema()).decode(&raw);
    for cut in [13usize, 257, 100_000] {
        let mut dec = ShardedUtf8Decoder::new(ds.schema(), 4, true);
        let mut out = RowBlock::new(ds.schema());
        for c in raw.chunks(cut) {
            dec.feed_into(c, &mut out);
        }
        dec.finish_into(&mut out);
        assert_eq!(out.to_rows(), oracle.rows, "cut {cut}");
    }
}

#[test]
fn missing_trailing_newline_consistent_across_paths() {
    let ds = SynthDataset::generate(SynthConfig::small(120));
    let mut raw = utf8::encode_dataset(&ds);
    raw.pop(); // drop the final `\n`: the last row completes at finish
    assert_all_paths_match(ds.schema(), &raw, "no trailing newline");
}

#[test]
fn malformed_rows_classified_identically_across_paths() {
    // One row per defect kind, with the expected stream-absolute offset
    // computed while the buffer is built. Scalar and SWAR loops, every
    // thread count and every chunk cut must classify each row with the
    // same kind at the same offset — the containment contract.
    let schema = Schema::new(2, 2);
    let mut raw: Vec<u8> = Vec::new();
    let mut expected: Vec<(u64, RowErrorKind, u64)> = Vec::new();
    let mut bad_lines: Vec<Vec<u8>> = Vec::new();

    raw.extend_from_slice(b"0\t1\t2\tdeadbeef\tcafef00d\n"); // row 0: clean

    // row 1: illegal byte mid-field ('Z' after "1\t3\t").
    expected.push((raw.len() as u64 + 4, RowErrorKind::IllegalByte, 1));
    bad_lines.push(b"1\t3\tZ4\t5\t6\n".to_vec());
    raw.extend_from_slice(b"1\t3\tZ4\t5\t6\n");

    // row 2: short row (4 fields where the schema needs 5); the defect
    // offset is the row's first byte.
    expected.push((raw.len() as u64, RowErrorKind::WrongFieldCount, 2));
    bad_lines.push(b"0\t7\t8\t9\n".to_vec());
    raw.extend_from_slice(b"0\t7\t8\t9\n");

    // row 3: dense decimal past u32::MAX; the defect offset is the
    // overflowing field's first byte (after "1\t").
    expected.push((raw.len() as u64 + 2, RowErrorKind::NumericOverflow, 3));
    bad_lines.push(b"1\t99999999999\t1\t2\t3\n".to_vec());
    raw.extend_from_slice(b"1\t99999999999\t1\t2\t3\n");

    // row 4: one sparse field longer than MAX_FIELD_BYTES.
    let mut line = b"0\t1\t2\t3\t".to_vec();
    expected.push((raw.len() as u64 + line.len() as u64, RowErrorKind::OversizedField, 4));
    line.extend_from_slice(&[b'a'; 70]);
    line.push(b'\n');
    bad_lines.push(line.clone());
    raw.extend_from_slice(&line);

    raw.extend_from_slice(b"1\t5\t6\t7\t8\n"); // row 5: clean

    for swar in [false, true] {
        for threads in [1usize, 2, 8] {
            for chunk in [1usize, 7, 4096, usize::MAX] {
                for policy in
                    [ErrorPolicy::Zero, ErrorPolicy::Skip, ErrorPolicy::Quarantine]
                {
                    let opts = DecodeOptions {
                        threads,
                        swar,
                        errors: ErrorConfig { policy, ..ErrorConfig::default() },
                    };
                    let (rows, tally) = chunked_decode(schema, &raw, chunk, opts);
                    let ctx = format!(
                        "swar={swar} threads={threads} chunk={chunk} policy={}",
                        policy.name()
                    );
                    let got: Vec<(u64, RowErrorKind, u64)> = tally
                        .errors
                        .recorded
                        .iter()
                        .map(|e| (e.offset, e.kind, e.row))
                        .collect();
                    assert_eq!(got, expected, "{ctx}: row-error log");
                    assert_eq!(tally.errors.total, 4, "{ctx}: total");
                    assert_eq!(tally.rows_seen, 6, "{ctx}: rows seen");
                    match policy {
                        ErrorPolicy::Zero => {
                            assert_eq!(rows.len(), 6, "{ctx}: zero keeps every row")
                        }
                        _ => {
                            assert_eq!(rows.len(), 2, "{ctx}: only the clean rows");
                            assert_eq!(rows[0].dense, vec![1, 2], "{ctx}: first kept row");
                            assert_eq!(rows[1].dense, vec![5, 6], "{ctx}: last kept row");
                        }
                    }
                    if policy == ErrorPolicy::Quarantine {
                        let lines: Vec<&[u8]> =
                            tally.quarantined.iter().map(|q| q.bytes.as_slice()).collect();
                        let want: Vec<&[u8]> =
                            bad_lines.iter().map(|l| l.as_slice()).collect();
                        assert_eq!(lines, want, "{ctx}: captured raw rows");
                        let offs: Vec<u64> =
                            tally.quarantined.iter().map(|q| q.offset).collect();
                        // Rows 1..=4 sit back to back right after row 0.
                        let mut row_starts = Vec::new();
                        let mut pos = b"0\t1\t2\tdeadbeef\tcafef00d\n".len() as u64;
                        for l in &bad_lines {
                            row_starts.push(pos);
                            pos += l.len() as u64;
                        }
                        assert_eq!(offs, row_starts, "{ctx}: quarantine row starts");
                    }
                }
            }
        }
    }
}
