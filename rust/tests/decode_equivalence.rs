//! Decode equivalence suite: the SWAR wide-word loop, the row-sharded
//! parallel decoder and the `ParallelDecoder` fast path must be
//! bit-identical to the byte-at-a-time scalar oracle — rows,
//! fill-missing zeros *and* illegal-byte positions — across widths,
//! shard counts and chunk boundaries that split rows mid-field. The
//! accelerator's modeled cycle counts must be untouched by any software
//! speedup. CI runs this under `--release` as well: the SWAR bit tricks
//! must hold with optimizations on, not just in the debug profile.

use piper::accel::InputFormat;
use piper::data::{utf8, RowBlock, Schema, SynthConfig, SynthDataset};
use piper::decode::{ParallelDecoder, ScalarDecoder, ShardedUtf8Decoder};
use piper::pipeline::{ChunkDecoder, DecodeOptions};
use piper::util::XorShift64;

const WIDTHS: [usize; 4] = [1, 2, 4, 8];
const THREADS: [usize; 4] = [1, 2, 3, 8];
const CHUNKS: [usize; 5] = [1, 7, 64, 4096, usize::MAX];

/// Decode `raw` through the chunked engine front with the given decode
/// options, collecting all rows and the illegal log.
fn chunked_decode(
    schema: Schema,
    raw: &[u8],
    chunk: usize,
    opts: DecodeOptions,
) -> (Vec<piper::data::DecodedRow>, piper::decode::IllegalLog) {
    let mut dec = ChunkDecoder::with_options(InputFormat::Utf8, schema, opts);
    let mut out = RowBlock::new(schema);
    for c in raw.chunks(chunk.clamp(1, raw.len())) {
        dec.feed_into(c, &mut out).expect("utf8 decode is infallible");
    }
    let illegal = dec.finish_into(&mut out).expect("utf8 finish is infallible");
    (out.to_rows(), illegal)
}

/// Every path over one buffer: rows, error log and cycles pinned to the
/// scalar oracle.
fn assert_all_paths_match(schema: Schema, raw: &[u8], tag: &str) {
    let oracle = ScalarDecoder::new(schema).decode(raw);
    assert_eq!(oracle.cycles, raw.len() as u64, "{tag}: scalar II = 1 byte/cycle");

    for w in WIDTHS {
        let par = ParallelDecoder::with_width(schema, w).decode(raw);
        assert_eq!(par.rows, oracle.rows, "{tag}: width {w} rows");
        assert_eq!(par.illegal, oracle.illegal, "{tag}: width {w} error positions");
        assert_eq!(
            par.cycles,
            (raw.len() as u64).div_ceil(w as u64),
            "{tag}: width {w} cycles must stay the hardware model's"
        );
        let groups = ParallelDecoder::with_width(schema, w).decode_by_groups(raw);
        assert_eq!(groups.rows, oracle.rows, "{tag}: width {w} per-group rows");
        assert_eq!(groups.cycles, par.cycles, "{tag}: width {w} per-group cycles");
        assert_eq!(groups.illegal, oracle.illegal, "{tag}: width {w} per-group errors");
    }

    for threads in THREADS {
        for swar in [false, true] {
            for chunk in CHUNKS {
                let opts = DecodeOptions { threads, swar };
                let (rows, illegal) = chunked_decode(schema, raw, chunk, opts);
                let ctx = format!("{tag}: threads={threads} swar={swar} chunk={chunk}");
                assert_eq!(rows, oracle.rows, "{ctx} rows");
                assert_eq!(illegal, oracle.illegal, "{ctx} error positions");
            }
        }
    }
}

#[test]
fn well_formed_datasets_bit_identical() {
    for (nd, ns, rows) in [(13usize, 26usize, 600usize), (1, 1, 400), (0, 4, 300), (5, 0, 300)] {
        let mut cfg = SynthConfig::small(rows);
        cfg.schema = Schema::new(nd, ns);
        cfg.missing_rate = 0.25; // exercise FillMissing zeros heavily
        let ds = SynthDataset::generate(cfg);
        let raw = utf8::encode_dataset(&ds);
        let decoded = ScalarDecoder::new(ds.schema()).decode(&raw);
        assert_eq!(decoded.rows, ds.rows, "oracle round-trip {nd}x{ns}");
        assert!(decoded.illegal.is_empty());
        assert_all_paths_match(ds.schema(), &raw, &format!("schema {nd}x{ns}"));
    }
}

#[test]
fn random_legal_soup_bit_identical() {
    // Legal bytes only, but no row structure: fields longer than 8
    // nibbles (register wrap), empty rows, minus signs mid-field,
    // columns beyond the schema — the state machines must agree on all
    // of it, including across shard seams.
    let legal = b"\t\n-0123456789abcdef";
    let schema = Schema::new(3, 3);
    let mut rng = XorShift64::new(0x5AAB_0001);
    for case in 0..40 {
        let len = 200 + rng.below(3_000) as usize;
        let raw: Vec<u8> =
            (0..len).map(|_| legal[rng.below(legal.len() as u64) as usize]).collect();
        assert_all_paths_match(schema, &raw, &format!("legal soup case {case}"));
    }
}

#[test]
fn random_arbitrary_bytes_bit_identical_with_error_positions() {
    // Fully adversarial: all 256 byte values, so the SWAR classifier's
    // exactness (high-bit lanes, zero-test false positives) is load
    // bearing, and every path must report the same skipped offsets.
    let schema = Schema::new(2, 2);
    let mut rng = XorShift64::new(0xD15C0);
    for case in 0..40 {
        let len = 100 + rng.below(2_000) as usize;
        let mut raw: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // Sprinkle newlines so rows actually terminate now and then.
        for i in (0..raw.len()).step_by(97) {
            raw[i] = b'\n';
        }
        let oracle = ScalarDecoder::new(schema).decode(&raw);
        assert!(oracle.illegal.total > 0, "case {case} should contain illegal bytes");
        assert_all_paths_match(schema, &raw, &format!("arbitrary soup case {case}"));
    }
}

#[test]
fn sharded_error_offsets_are_chunk_absolute() {
    // Regression for the sharded path: illegal bytes at known absolute
    // offsets, decoded with chunk boundaries that split rows mid-field
    // and enough volume that chunks really shard. Offsets must be
    // reported within the original stream, never within a shard.
    let schema = Schema::new(1, 1);
    let mut raw = Vec::new();
    let mut expected = Vec::new();
    for i in 0..40_000u32 {
        let mut line = format!("{}\t{:07}\tcafef00d\n", i % 2, i).into_bytes();
        if i % 9_000 == 1_234 {
            expected.push(raw.len() as u64 + 4);
            line[4] = b'Z'; // corrupt a dense digit
        }
        raw.extend_from_slice(&line);
    }
    assert!(!expected.is_empty());
    let oracle = ScalarDecoder::new(schema).decode(&raw);
    let got_oracle: Vec<u64> = oracle.illegal.recorded.iter().map(|b| b.offset).collect();
    assert_eq!(got_oracle, expected, "oracle offsets");

    for threads in [2usize, 4, 8] {
        // One big feed (chunk interior shards) and mid-row cut feeds.
        for chunk in [usize::MAX, 1 << 20, 300_001] {
            let opts = DecodeOptions { threads, swar: true };
            let (rows, illegal) = chunked_decode(schema, &raw, chunk, opts);
            assert_eq!(rows, oracle.rows, "threads={threads} chunk={chunk}");
            let got: Vec<u64> = illegal.recorded.iter().map(|b| b.offset).collect();
            assert_eq!(got, expected, "threads={threads} chunk={chunk} offsets");
            assert_eq!(illegal.total, expected.len() as u64);
        }
    }
}

#[test]
fn sharded_decoder_streams_like_one_shot() {
    // Drive the sharded decoder directly (not through ChunkDecoder)
    // with pathological chunk cuts; the carried row must cross every
    // boundary intact.
    let ds = SynthDataset::generate(SynthConfig::small(800));
    let raw = utf8::encode_dataset(&ds);
    let oracle = ScalarDecoder::new(ds.schema()).decode(&raw);
    for cut in [13usize, 257, 100_000] {
        let mut dec = ShardedUtf8Decoder::new(ds.schema(), 4, true);
        let mut out = RowBlock::new(ds.schema());
        for c in raw.chunks(cut) {
            dec.feed_into(c, &mut out);
        }
        dec.finish_into(&mut out);
        assert_eq!(out.to_rows(), oracle.rows, "cut {cut}");
    }
}

#[test]
fn missing_trailing_newline_consistent_across_paths() {
    let ds = SynthDataset::generate(SynthConfig::small(120));
    let mut raw = utf8::encode_dataset(&ds);
    raw.pop(); // drop the final `\n`: the last row completes at finish
    assert_all_paths_match(ds.schema(), &raw, "no trailing newline");
}
