//! Figure 9 — end-to-end CPU / GPU / PIPER comparison across the four
//! sub-figures: (a) UTF-8+5K, (b) UTF-8+1M, (c) binary+5K, (d) binary+1M.
//!
//! CPU rows are measured on this machine and *also* projected to paper
//! scale; GPU and PIPER rows are timing-model outputs at paper scale
//! (tagged sim). Speedups are computed against the best CPU row, next to
//! the paper's reported speedups.

use piper::accel::{dataflow, host::HostModel, network, InputFormat, Mode, PiperConfig};
use piper::benchutil::{bench_rows, dataset, paper};
use piper::cpu_baseline::{
    profile_single_thread, project, BaselineConfig, ConfigKind, ServerModel, SimDisk,
};
use piper::data::{binary, utf8};
use piper::gpu_sim::GpuModel;
use piper::ops::Modulus;
use piper::report::{fmt_duration, fmt_speedup, Table};
use std::time::Duration;

struct SubFig {
    name: &'static str,
    input: InputFormat,
    vocab: Modulus,
    paper_speedups: &'static str,
    /// Paper Table 3 best-CPU pure-compute throughput (rows/s) — the
    /// reference the paper's Fig. 9 speedups are computed against
    /// (Meta's python pipeline on the 128-core EPYC).
    paper_cpu_best_rps: f64,
}

fn main() {
    let rows = bench_rows(100_000);
    let ds = dataset(rows);
    let raw_utf8 = utf8::encode_dataset(&ds);
    let raw_bin = binary::encode_dataset(&ds);

    let subs = [
        SubFig { name: "9a", input: InputFormat::Utf8, vocab: Modulus::VOCAB_5K,
                 paper_speedups: "paper: local 2.5×/2.0×, network 5.1×",
                 paper_cpu_best_rps: 4.82e5 },
        SubFig { name: "9b", input: InputFormat::Utf8, vocab: Modulus::VOCAB_1M,
                 paper_speedups: "paper: network 4.7×",
                 paper_cpu_best_rps: 2.06e5 },
        SubFig { name: "9c", input: InputFormat::Binary, vocab: Modulus::VOCAB_5K,
                 paper_speedups: "paper: local 5.0×, network 71.3×; GPU gap 4.8~20.3×",
                 paper_cpu_best_rps: 5.09e5 },
        SubFig { name: "9d", input: InputFormat::Binary, vocab: Modulus::VOCAB_1M,
                 paper_speedups: "paper: network 25.7×",
                 paper_cpu_best_rps: 2.20e5 },
    ];

    for sub in &subs {
        let raw: &[u8] = match sub.input {
            InputFormat::Utf8 => &raw_utf8,
            InputFormat::Binary => &raw_bin,
        };
        let paper_bytes = match sub.input {
            InputFormat::Utf8 => paper::UTF8_BYTES,
            InputFormat::Binary => paper::BINARY_BYTES,
        };

        // --- best CPU: single-thread components measured here, thread
        //     scaling projected to the paper's 128-core EPYC -------------
        let kind = match sub.input {
            InputFormat::Utf8 => ConfigKind::II,
            InputFormat::Binary => ConfigKind::III,
        };
        let profile = profile_single_thread(&BaselineConfig::new(kind, 1, sub.vocab), raw)
            .scaled_to(paper::ROWS);
        let server = ServerModel::paper_epyc();
        let disk = SimDisk::default();
        let mut best_cpu = Duration::MAX;
        let mut best_threads = 0;
        for n in [1usize, 8, 16, 32, 64, 128] {
            let t = project(&profile, kind, n, &disk, &server, false).total();
            if t < best_cpu {
                best_cpu = t;
                best_threads = n;
            }
        }

        // --- GPU model at paper scale -----------------------------------
        let g = GpuModel::default();
        let gpu_time = {
            let convert = match sub.input {
                InputFormat::Utf8 => paper::UTF8_BYTES as f64 / g.convert_bps,
                InputFormat::Binary => 0.0,
            };
            let transfer = 2.0 * paper::BINARY_BYTES as f64 / g.pcie_bps;
            let sparse_vals = (paper::ROWS * 26) as f64;
            let dense_vals = (paper::ROWS * 13) as f64;
            let stream = (2.0 * sparse_vals + 2.0 * dense_vals) * 8.0
                / (g.hbm_bps * g.stream_efficiency);
            let vocab = sparse_vals / g.sort_keys_per_sec + sparse_vals * 16.0 / g.random_bps;
            let dispatch = g.per_op_dispatch.as_secs_f64() * (4.0 * 26.0 + 3.0 * 13.0);
            Duration::from_secs_f64(convert + transfer + stream + vocab + dispatch)
        };

        // --- PIPER modes at paper scale ---------------------------------
        let uniq = match sub.vocab.range {
            r if r > 100_000 => 26 * 700_000,
            r => 26 * r as usize,
        };
        let piper = |mode: Mode| -> Duration {
            let cfg = PiperConfig::paper(mode, sub.input, sub.vocab);
            let k = dataflow::model_timing(&cfg, paper_bytes, paper::ROWS, uniq).seconds();
            match mode {
                Mode::Network => network::stream_time(&cfg, paper_bytes, k),
                _ => HostModel::default()
                    .local_breakdown(&cfg, paper_bytes, paper::ROWS, k)
                    .total(),
            }
        };

        // The paper's Fig. 9 reference: its own python CPU baseline on
        // the 128-core EPYC (Table 3 best rows/s → seconds over 46M rows).
        let paper_cpu = Duration::from_secs_f64(paper::ROWS as f64 / sub.paper_cpu_best_rps);

        let mut t = Table::new(
            &format!(
                "Fig. {} — e2e at paper scale ({:?}, vocab {})",
                sub.name, sub.input, sub.vocab.range
            ),
            &["platform", "e2e time", "vs paper CPU", "vs rust CPU"],
        );
        let mut add = |name: String, d: Duration| {
            t.row(&[
                name,
                fmt_duration(d),
                fmt_speedup(paper_cpu.as_secs_f64() / d.as_secs_f64()),
                fmt_speedup(best_cpu.as_secs_f64() / d.as_secs_f64()),
            ]);
        };
        add("CPU paper baseline (128c python) [lit]".into(), paper_cpu);
        add(format!("CPU rust, this repo ({best_threads}t proj) [meas+sim]"), best_cpu);
        add("GPU V100 [sim]".into(), gpu_time);
        if sub.vocab.range <= 100_000 {
            // paper runs local mode only for small vocab (Table 2)
            add("PIPER local, decode-in-kernel [sim]".into(), piper(Mode::LocalDecodeInKernel));
            if sub.input == InputFormat::Utf8 {
                add("PIPER local, decode-in-host [sim]".into(), piper(Mode::LocalDecodeInHost));
            }
        }
        add("PIPER network [sim]".into(), piper(Mode::Network));
        t.note(sub.paper_speedups);
        t.note("`vs paper CPU` is the paper's comparison (its python pipeline); the rust CPU");
        t.note("row is this repo's own optimized baseline — a reproduction finding: native");
        t.note("software closes much of the gap the paper attributes to CPUs per se");
        t.note(&format!(
            "rust CPU: 1-thread components measured over {rows} rows here, projected to 128 cores"
        ));
        t.print();
        println!();
    }
}
