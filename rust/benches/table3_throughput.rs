//! Table 3 — pure-computation throughput (rows/s): CPU at
//! {1,8,16,32,64,128} threads × Configs I/II/III × vocab {5K,1M}, plus
//! PIPER local and network.
//!
//! CPU protocol: single-thread work components measured on this machine
//! (median of reps), projected to the paper's 128-core EPYC (tagged sim
//! for T>1 — this box may have one core). PIPER rows: kernel model at
//! paper scale. The paper's own numbers and the ratio are printed
//! alongside; absolute CPU ratios reflect rust-vs-python single-core
//! speed, the *shape* across threads/configs is the reproduction target.

use piper::accel::{dataflow, InputFormat, Mode, PiperConfig};
use piper::benchutil::{bench_reps, bench_rows, dataset, paper};
use piper::cpu_baseline::{
    profile_single_thread, project, BaselineConfig, ConfigKind, ServerModel, SimDisk,
};
use piper::data::{binary, utf8};
use piper::ops::Modulus;
use piper::report::{fmt_rows_per_sec, Table};

/// Paper Table 3 values (rows/s) for side-by-side printing.
fn paper_value(vocab: u32, kind: ConfigKind, threads: usize) -> Option<f64> {
    let v = match (vocab, kind) {
        (5_000, ConfigKind::I) => [1.84e4, 1.32e5, 2.32e5, 4.32e5, 7.39e5, 9.75e5],
        (5_000, ConfigKind::II) => [4.02e4, 2.30e5, 3.27e5, 4.16e5, 4.82e5, 4.53e5],
        (5_000, ConfigKind::III) => [4.96e4, 2.61e5, 3.69e5, 4.67e5, 5.09e5, 4.92e5],
        (1_000_000, ConfigKind::I) => [1.50e4, 1.08e5, 1.52e5, 1.93e5, 2.01e5, 1.98e5],
        (1_000_000, ConfigKind::II) => [3.81e4, 1.71e5, 2.05e5, 2.06e5, 1.99e5, 1.83e5],
        (1_000_000, ConfigKind::III) => [4.51e4, 1.92e5, 2.15e5, 2.20e5, 2.00e5, 1.87e5],
        _ => return None,
    };
    let idx = [1usize, 8, 16, 32, 64, 128].iter().position(|&t| t == threads)?;
    Some(v[idx])
}

fn main() {
    let rows = bench_rows(120_000);
    let reps = bench_reps(3);
    let ds = dataset(rows);
    let raw_utf8 = utf8::encode_dataset(&ds);
    let raw_bin = binary::encode_dataset(&ds);
    let threads = [1usize, 8, 16, 32, 64, 128];
    let server = ServerModel::paper_epyc();
    let disk = SimDisk::default();

    for vocab in [Modulus::VOCAB_5K, Modulus::VOCAB_1M] {
        let mut t = Table::new(
            &format!(
                "Table 3 — pure compute rows/s @46M rows, vocab {} (profiled {rows} rows ×{reps} [meas], threads>1 projected [sim])",
                vocab.range
            ),
            &["config", "threads", "this repo", "paper", "ratio", "shape vs paper"],
        );
        for kind in [ConfigKind::I, ConfigKind::II, ConfigKind::III] {
            let raw: &[u8] = if kind.binary_input() { &raw_bin } else { &raw_utf8 };
            let cfg = BaselineConfig::new(kind, 1, vocab);
            // median-of-reps profile
            let mut profiles: Vec<_> =
                (0..reps).map(|_| profile_single_thread(&cfg, raw)).collect();
            profiles.sort_by_key(|p| p.gv_parse + p.gv_observe + p.av);
            let profile = profiles[profiles.len() / 2].scaled_to(paper::ROWS);

            let t1 = project(&profile, kind, 1, &disk, &server, true).compute();
            for &n in &threads {
                let c = project(&profile, kind, n, &disk, &server, true).compute();
                let rps = paper::ROWS as f64 / c.as_secs_f64();
                let (p, ratio, shape) = match paper_value(vocab.range, kind, n) {
                    Some(p) => {
                        // shape = our speedup-vs-1t / paper's speedup-vs-1t
                        let ours = t1.as_secs_f64() / c.as_secs_f64();
                        let paper1 = paper_value(vocab.range, kind, 1).unwrap();
                        let theirs = p / paper1;
                        (fmt_rows_per_sec(p), format!("{:.2}", rps / p),
                         format!("{:.2}", ours / theirs))
                    }
                    None => ("-".into(), "-".into(), "-".into()),
                };
                t.row(&[kind.name().into(), n.to_string(), fmt_rows_per_sec(rps), p, ratio, shape]);
            }
        }
        // PIPER kernel throughput at paper scale.
        let uniq = if vocab.range > 100_000 { 26 * 700_000 } else { 26 * vocab.range as usize };
        for (label, mode, input, paper_rps) in [
            ("FPGA local (UTF-8)", Mode::LocalDecodeInKernel, InputFormat::Utf8,
             if vocab.range == 5_000 { Some(1.87e6) } else { None }),
            ("FPGA network (UTF-8)", Mode::Network, InputFormat::Utf8,
             Some(if vocab.range == 5_000 { 1.56e6 } else { 8.45e5 })),
            ("FPGA local (binary)", Mode::LocalDecodeInKernel, InputFormat::Binary,
             if vocab.range == 5_000 { Some(1.77e7) } else { None }),
            ("FPGA network (binary)", Mode::Network, InputFormat::Binary,
             Some(if vocab.range == 5_000 { 2.36e7 } else { 4.99e6 })),
        ] {
            if vocab.range > 100_000 && mode == Mode::LocalDecodeInKernel {
                continue; // paper Table 2: no local runs at 1M
            }
            let cfg = PiperConfig::paper(mode, input, vocab);
            let bytes = match input {
                InputFormat::Utf8 => paper::UTF8_BYTES,
                InputFormat::Binary => paper::BINARY_BYTES,
            };
            let k = dataflow::model_timing(&cfg, bytes, paper::ROWS, uniq);
            let rps = paper::ROWS as f64 / k.seconds().as_secs_f64();
            let (p, ratio) = match paper_rps {
                Some(p) => (fmt_rows_per_sec(p), format!("{:.2}", rps / p)),
                None => ("-".into(), "-".into()),
            };
            t.row(&[format!("{label} [sim]"), "-".into(), fmt_rows_per_sec(rps), p,
                    ratio, "-".into()]);
        }
        t.note("`ratio` = this repo / paper (absolute; rust 1-core ≈ 10-35× python explains CPU offsets)");
        t.note("`shape vs paper` = our thread-speedup / paper's thread-speedup (1.0 = same curve)");
        t.print();
        println!();
    }
}
