//! Columnar data-plane bench: the old row-wise `Vec<DecodedRow>`
//! representation vs the column-major `RowBlock` plane, over the full
//! decode → GenVocab → ApplyVocab hot path (two passes, like the
//! engine's two-loop design), single-threaded so the representation —
//! not parallelism — is what's measured.
//!
//! What to look for:
//!   * binary input: the row-wise path pays two heap `Vec`s per row plus
//!     an `extend_from_slice`+`drain` staging memmove per chunk; the
//!     columnar path bulk-copies words straight into column planes and
//!     recycles one scratch block — the ISSUE's ≥2× target lives here;
//!   * UTF-8 input: byte-at-a-time decode dominates, so the win is
//!     smaller but the allocation delta still shows;
//!   * both paths must produce identical checksums (bit-identical
//!     outputs) — asserted, not assumed.

use std::time::Instant;

use piper::accel::InputFormat;
use piper::benchutil::{bench_reps, bench_rows, dataset, median};
use piper::data::row::ProcessedColumns;
use piper::data::{binary, utf8, DecodedRow, RowBlock, Schema};
use piper::decode::RowAssembler;
use piper::ops::{log1p, neg2zero, HashVocab, Modulus, PipelineSpec, Vocab};
use piper::pipeline::{ChunkDecoder, ChunkState, Plan};
use piper::report::{fmt_duration, fmt_rows_per_sec, fmt_speedup, Table};

const CHUNK_ROWS: usize = 16 * 1024;

/// Fold a processed block into a cheap order-sensitive checksum so the
/// sink cost is identical for both paths and outputs stay comparable.
fn fold(sum: &mut u64, cols: &ProcessedColumns) {
    for &l in &cols.labels {
        *sum = sum.wrapping_mul(31).wrapping_add(l as u64);
    }
    for col in &cols.sparse {
        for &v in col {
            *sum = sum.wrapping_mul(31).wrapping_add(v as u64);
        }
    }
    for col in &cols.dense {
        for &v in col {
            *sum = sum.wrapping_mul(31).wrapping_add(v.to_bits() as u64);
        }
    }
}

// ---------------------------------------------------------------------
// The OLD data plane: per-row decode, row-wise GV/AV (what the engine
// did before the RowBlock redesign — reproduced here as the baseline).
// ---------------------------------------------------------------------

/// Chunked decoder to `Vec<DecodedRow>`: binary stages bytes through a
/// partial buffer (`extend` + `drain` per chunk) and allocates two
/// `Vec`s per row; UTF-8 assembles rows then materializes them.
struct RowWiseDecoder {
    schema: Schema,
    input: InputFormat,
    asm: RowAssembler,
    partial: Vec<u8>,
}

impl RowWiseDecoder {
    fn new(schema: Schema, input: InputFormat) -> Self {
        RowWiseDecoder { schema, input, asm: RowAssembler::new(schema), partial: Vec::new() }
    }

    fn feed(&mut self, chunk: &[u8]) -> Vec<DecodedRow> {
        match self.input {
            InputFormat::Utf8 => {
                self.asm.feed_bytes(chunk);
                self.asm.take_rows()
            }
            InputFormat::Binary => {
                self.partial.extend_from_slice(chunk);
                let rb = self.schema.binary_row_bytes();
                let full = self.partial.len() / rb * rb;
                let rows = binary::decode_bytes(&self.partial[..full], self.schema).unwrap();
                self.partial.drain(..full);
                rows
            }
        }
    }
}

struct RowWiseState {
    modulus: Modulus,
    vocabs: Vec<HashVocab>,
}

impl RowWiseState {
    fn observe(&mut self, rows: &[DecodedRow]) {
        for row in rows {
            for (c, &s) in row.sparse.iter().enumerate() {
                self.vocabs[c].observe(self.modulus.apply(s));
            }
        }
    }

    fn process(&self, schema: Schema, rows: &[DecodedRow]) -> ProcessedColumns {
        let mut out = ProcessedColumns::with_schema(schema);
        out.labels.reserve(rows.len());
        for row in rows {
            out.labels.push(row.label);
            for (c, &d) in row.dense.iter().enumerate() {
                out.dense[c].push(log1p(neg2zero(d)));
            }
            for (c, &s) in row.sparse.iter().enumerate() {
                out.sparse[c].push(self.vocabs[c].apply(self.modulus.apply(s)).unwrap_or(0));
            }
        }
        out
    }
}

fn run_rowwise(
    raw: &[u8],
    schema: Schema,
    input: InputFormat,
    m: Modulus,
    cb: usize,
) -> (u64, usize) {
    let mut state = RowWiseState {
        modulus: m,
        vocabs: (0..schema.num_sparse).map(|_| HashVocab::new()).collect(),
    };
    let mut dec = RowWiseDecoder::new(schema, input);
    let mut rows_seen = 0usize;
    for chunk in raw.chunks(cb) {
        let rows = dec.feed(chunk);
        state.observe(&rows);
        rows_seen += rows.len();
    }
    let mut sum = 0u64;
    let mut dec = RowWiseDecoder::new(schema, input);
    for chunk in raw.chunks(cb) {
        let rows = dec.feed(chunk);
        let cols = state.process(schema, &rows);
        fold(&mut sum, &cols);
    }
    (sum, rows_seen)
}

// ---------------------------------------------------------------------
// The NEW data plane: ChunkDecoder → reused RowBlock → ChunkState.
// ---------------------------------------------------------------------

fn run_columnar(raw: &[u8], plan: &Plan) -> (u64, usize) {
    // The engine's own chunking estimate — both paths chunk identically.
    let cb = plan.chunk_bytes();
    let mut state = ChunkState::new(plan);
    let mut block = RowBlock::with_capacity(plan.schema(), CHUNK_ROWS);
    let mut rows_seen = 0usize;
    let mut dec = ChunkDecoder::new(plan.input, plan.schema());
    for chunk in raw.chunks(cb) {
        block.clear();
        dec.feed_into(chunk, &mut block).unwrap();
        state.observe(&block);
        rows_seen += block.num_rows();
    }
    block.clear();
    dec.finish_into(&mut block).unwrap();
    state.observe(&block);
    rows_seen += block.num_rows();

    let mut sum = 0u64;
    let mut dec = ChunkDecoder::new(plan.input, plan.schema());
    for chunk in raw.chunks(cb) {
        block.clear();
        dec.feed_into(chunk, &mut block).unwrap();
        fold(&mut sum, &state.process(&block));
    }
    block.clear();
    dec.finish_into(&mut block).unwrap();
    fold(&mut sum, &state.process(&block));
    (sum, rows_seen)
}

fn main() {
    let rows = bench_rows(200_000);
    let reps = bench_reps(3);
    let ds = dataset(rows);
    let m = Modulus::VOCAB_5K;
    let spec = PipelineSpec::dlrm(m.range);

    let mut t = Table::new(
        &format!(
            "row-wise Vec<DecodedRow> vs columnar RowBlock — decode+GV+AV, \
             1 thread, {rows} rows, median of {reps} [meas]"
        ),
        &["input", "row-wise", "columnar", "rows/s (columnar)", "speedup"],
    );

    for input in [InputFormat::Binary, InputFormat::Utf8] {
        let raw = match input {
            InputFormat::Binary => binary::encode_dataset(&ds),
            InputFormat::Utf8 => utf8::encode_dataset(&ds),
        };
        let plan = Plan::compile(spec.clone(), ds.schema(), input, CHUNK_ROWS)
            .expect("DLRM spec compiles against the synth schema");

        // Correctness gate: identical checksums before timing anything.
        let cb = plan.chunk_bytes();
        let (sum_old, n_old) = run_rowwise(&raw, ds.schema(), input, m, cb);
        let (sum_new, n_new) = run_columnar(&raw, &plan);
        assert_eq!(n_old, rows, "row-wise row count");
        assert_eq!(n_new, rows, "columnar row count");
        assert_eq!(sum_old, sum_new, "representations must be bit-identical");

        let old = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    std::hint::black_box(run_rowwise(&raw, ds.schema(), input, m, cb));
                    t0.elapsed()
                })
                .collect(),
        );
        let new = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    std::hint::black_box(run_columnar(&raw, &plan));
                    t0.elapsed()
                })
                .collect(),
        );
        let speedup = old.as_secs_f64() / new.as_secs_f64().max(1e-12);
        t.row(&[
            format!("{input:?}"),
            fmt_duration(old),
            fmt_duration(new),
            fmt_rows_per_sec(rows as f64 / new.as_secs_f64().max(1e-12)),
            fmt_speedup(speedup),
        ]);
    }
    t.note("both paths: two passes (GenVocab rewind), identical checksums asserted");
    t.note("row-wise = pre-RowBlock engine: 2 heap Vecs/row + chunk staging memmove");
    t.print();
    println!();

    // ---- decode-threads × SWAR on/off sweep (decode stage only) --------
    // The EXPERIMENTS.md §Decode ablation: the same UTF-8 buffer through
    // the chunked decode front alone — scalar vs SWAR loop, 1..8 row
    // shards — all eight combinations checksum-verified identical.
    let raw = utf8::encode_dataset(&ds);
    let schema = ds.schema();
    let mb = raw.len() as f64 / (1024.0 * 1024.0);
    let mut t = Table::new(
        &format!(
            "decode stage: SWAR × decode threads — UTF-8, {rows} rows \
             ({mb:.1} MiB), median of {reps} [meas]"
        ),
        &["loop", "threads", "wallclock", "MiB/s", "speedup vs scalar-1"],
    );
    let mut want_sum = None;
    let mut scalar1 = None;
    for swar in [false, true] {
        for threads in [1usize, 2, 4, 8] {
            let (sum, n) = decode_only(schema, &raw, threads, swar);
            assert_eq!(n, rows, "row count (swar={swar} threads={threads})");
            match want_sum {
                None => want_sum = Some(sum),
                Some(w) => {
                    assert_eq!(sum, w, "decode checksum (swar={swar} threads={threads})")
                }
            }
            let d = median(
                (0..reps)
                    .map(|_| {
                        let t0 = Instant::now();
                        std::hint::black_box(decode_only(schema, &raw, threads, swar));
                        t0.elapsed()
                    })
                    .collect(),
            );
            let base = *scalar1.get_or_insert(d);
            t.row(&[
                if swar { "SWAR".into() } else { "scalar".to_string() },
                threads.to_string(),
                fmt_duration(d),
                format!("{:.0}", mb / d.as_secs_f64().max(1e-12)),
                fmt_speedup(base.as_secs_f64() / d.as_secs_f64().max(1e-12)),
            ]);
        }
    }
    t.note("decode only: raw chunks → RowBlock, no GV/AV — the tentpole's scope");
    t.note("all 8 combinations decode bit-identical blocks (checksummed)");
    t.print();
}

/// Decode `raw` through the chunked front exactly like the engine (1 MiB
/// chunks, one reused scratch block) and fold an order-sensitive
/// checksum over every decoded block. Returns `(checksum, rows)`.
fn decode_only(schema: Schema, raw: &[u8], threads: usize, swar: bool) -> (u64, usize) {
    use piper::pipeline::DecodeOptions;
    let mut dec = ChunkDecoder::with_options(
        InputFormat::Utf8,
        schema,
        DecodeOptions { threads, swar, ..Default::default() },
    );
    let mut block = RowBlock::with_capacity(schema, CHUNK_ROWS);
    let mut sum = 0xcbf29ce484222325u64;
    let mut rows = 0usize;
    let mut fold_block = |sum: &mut u64, block: &RowBlock| {
        let mut mix = |v: u64| {
            *sum ^= v;
            *sum = sum.wrapping_mul(0x100000001b3);
        };
        for &l in block.labels() {
            mix(l as u64);
        }
        for c in 0..schema.num_dense {
            for &v in block.dense_col(c) {
                mix(v as u64);
            }
        }
        for c in 0..schema.num_sparse {
            for &v in block.sparse_col(c) {
                mix(v as u64);
            }
        }
    };
    for chunk in raw.chunks(1 << 20) {
        block.clear();
        dec.feed_into(chunk, &mut block).expect("utf8 decode is infallible");
        rows += block.num_rows();
        fold_block(&mut sum, &block);
    }
    block.clear();
    dec.finish_into(&mut block).expect("utf8 finish is infallible");
    rows += block.num_rows();
    fold_block(&mut sum, &block);
    (sum, rows)
}
