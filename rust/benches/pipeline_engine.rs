//! Pipeline-engine bench: the streaming Source→Plan→Executor→Sink path
//! vs the one-shot adapter, across executors and chunk sizes.
//!
//! What to look for:
//!   * plan-once amortization — a reused `Pipeline` skips validation and
//!     capability checks on every submission;
//!   * chunk-size sweep — throughput of the bounded-channel engine as
//!     chunks shrink (channel overhead) and grow (less overlap);
//!   * bounded memory — a `CountSink` run holds one chunk + vocabularies,
//!     never the dataset or the output.

use std::time::Instant;

use piper::accel::{InputFormat, Mode};
use piper::benchutil::{bench_reps, bench_rows, dataset, median};
use piper::coordinator::{self, Backend, Experiment};
use piper::cpu_baseline::ConfigKind;
use piper::data::utf8;
use piper::ops::{Modulus, PipelineSpec};
use piper::pipeline::{CountSink, MemorySource, PipelineBuilder, SynthSource};
use piper::report::{fmt_duration, fmt_rows_per_sec, Table};

fn main() {
    let rows = bench_rows(100_000);
    let reps = bench_reps(3);
    let ds = dataset(rows);
    let raw = utf8::encode_dataset(&ds);
    let m = Modulus::VOCAB_5K;

    // ---- executors through the engine vs the one-shot adapter ----------
    let mut t = Table::new(
        &format!("engine vs one-shot adapter ({rows} rows, median of {reps}) [meas wallclock]"),
        &["backend", "one-shot run_backend", "pipeline (reused)", "rows/s (pipeline)"],
    );
    let backends = [
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ];
    let exp = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Utf8) };
    for backend in &backends {
        let one_shot = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    coordinator::run_backend(backend, &exp, &raw).expect("run_backend");
                    t0.elapsed()
                })
                .collect(),
        );
        // Plan once, submit `reps` times.
        let pipeline = coordinator::pipeline_for(backend, &exp).expect("plan");
        let reused = median(
            (0..reps)
                .map(|_| {
                    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                    let mut sink = CountSink::new();
                    let t0 = Instant::now();
                    pipeline.run(&mut src, &mut sink).expect("submission");
                    t0.elapsed()
                })
                .collect(),
        );
        t.row(&[
            backend.name(),
            fmt_duration(one_shot),
            fmt_duration(reused),
            fmt_rows_per_sec(rows as f64 / reused.as_secs_f64()),
        ]);
    }
    t.note("pipeline column uses CountSink: bounded memory end to end");
    t.print();
    println!();

    // ---- chunk-size sweep (CPU executor, the measured path) ------------
    let mut t = Table::new(
        "chunk-size sweep — CPU-4 Config I over the engine [meas]",
        &["chunk_rows", "chunks", "wallclock", "rows/s"],
    );
    for chunk_rows in [512usize, 4 * 1024, 32 * 1024, 256 * 1024] {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(chunk_rows)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
            .build()
            .expect("plan");
        let mut best = None;
        let mut chunks = 0;
        for _ in 0..reps {
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            let mut sink = CountSink::new();
            let t0 = Instant::now();
            let report = pipeline.run(&mut src, &mut sink).expect("submission");
            let d = t0.elapsed();
            chunks = report.chunks;
            best = Some(best.map_or(d, |b: std::time::Duration| b.min(d)));
        }
        let best = best.expect("reps >= 1");
        t.row(&[
            chunk_rows.to_string(),
            chunks.to_string(),
            fmt_duration(best),
            fmt_rows_per_sec(rows as f64 / best.as_secs_f64()),
        ]);
    }
    t.note("chunks = per-pass producer chunks; small chunks stress the bounded channel");
    t.print();
    println!();

    // ---- generator-fed run: no materialized dataset anywhere -----------
    let gen_rows = rows.max(50_000);
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(m.range))
        .input(InputFormat::Utf8)
        .chunk_rows(32 * 1024)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
        .build()
        .expect("plan");
    let mut src = SynthSource::new(piper::data::SynthConfig::small(gen_rows), InputFormat::Utf8);
    let mut sink = CountSink::new();
    let t0 = Instant::now();
    let report = pipeline.run(&mut src, &mut sink).expect("generator run");
    let d = t0.elapsed();
    println!(
        "generator → engine → CountSink: {} rows in {} ({}), resident state = vocabularies + ~{} raw chunks",
        report.rows,
        fmt_duration(d),
        fmt_rows_per_sec(report.rows as f64 / d.as_secs_f64()),
        4,
    );
}
