//! Pipeline-engine bench: the streaming Source→Plan→Executor→Sink path
//! vs the one-shot adapter, across executors and chunk sizes.
//!
//! What to look for:
//!   * plan-once amortization — a reused `Pipeline` skips validation and
//!     capability checks on every submission;
//!   * fused vs two-pass — the single-pass strategy deletes a whole
//!     decode+observe pass; on decode-dominated (UTF-8) input that is
//!     the bulk of the work, so fused must show a wall-clock win
//!     (outputs checksum-verified identical first);
//!   * chunk-size sweep — throughput of the bounded-channel engine as
//!     chunks shrink (channel overhead) and grow (less overlap);
//!   * bounded memory — a `CountSink` run holds one chunk + vocabularies,
//!     never the dataset or the output.

use std::time::{Duration, Instant};

use piper::accel::{InputFormat, Mode};
use piper::benchutil::{bench_reps, bench_rows, dataset, median};
use piper::coordinator::{self, Backend, Experiment};
use piper::cpu_baseline::ConfigKind;
use piper::data::row::ProcessedColumns;
use piper::data::utf8;
use piper::decode::ErrorPolicy;
use piper::ops::{Modulus, PipelineSpec};
use piper::net::stream::WireFormat;
use piper::pipeline::{CountSink, ExecStrategy, MemorySource, PipelineBuilder, SynthSource};
use piper::report::{fmt_duration, fmt_rows_per_sec, fmt_speedup, Table};
use piper::service::{run_service_loopback, ServiceConfig};

/// Order-sensitive checksum of the full output — the equivalence gate
/// for the strategy comparison.
fn checksum(cols: &ProcessedColumns) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    for &l in &cols.labels {
        mix(l as u64);
    }
    for col in &cols.dense {
        for &d in col {
            mix(d.to_bits() as u64);
        }
    }
    for col in &cols.sparse {
        for &s in col {
            mix(s as u64);
        }
    }
    h
}

fn main() {
    let rows = bench_rows(100_000);
    let reps = bench_reps(3);
    let ds = dataset(rows);
    let raw = utf8::encode_dataset(&ds);
    let m = Modulus::VOCAB_5K;

    // ---- executors through the engine vs the one-shot adapter ----------
    let mut t = Table::new(
        &format!("engine vs one-shot adapter ({rows} rows, median of {reps}) [meas wallclock]"),
        &["backend", "one-shot run_backend", "pipeline (reused)", "rows/s (pipeline)"],
    );
    let backends = [
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ];
    let exp = Experiment { schema: ds.schema(), ..Experiment::new(m, InputFormat::Utf8) };
    for backend in &backends {
        let one_shot = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    coordinator::run_backend(backend, &exp, &raw).expect("run_backend");
                    t0.elapsed()
                })
                .collect(),
        );
        // Plan once, submit `reps` times.
        let pipeline = coordinator::pipeline_for(backend, &exp).expect("plan");
        let reused = median(
            (0..reps)
                .map(|_| {
                    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                    let mut sink = CountSink::new();
                    let t0 = Instant::now();
                    pipeline.run(&mut src, &mut sink).expect("submission");
                    t0.elapsed()
                })
                .collect(),
        );
        t.row(&[
            backend.name(),
            fmt_duration(one_shot),
            fmt_duration(reused),
            fmt_rows_per_sec(rows as f64 / reused.as_secs_f64()),
        ]);
    }
    t.note("pipeline column uses CountSink: bounded memory end to end");
    t.print();
    println!();

    // ---- fused vs two-pass (the execution-strategy comparison) ---------
    // Decode-dominated input: UTF-8 on the measured CPU path. The fused
    // strategy runs one decode pass instead of two; outputs are
    // checksum-verified identical before any time is reported.
    let mut t = Table::new(
        &format!("fused vs two-pass — UTF-8, {rows} rows, median of {reps} [meas wallclock]"),
        &["backend", "two-pass", "fused", "speedup", "fused observe/process"],
    );
    for backend in [
        Backend::Cpu { kind: ConfigKind::I, threads: 1 },
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Piper { mode: Mode::Network },
    ] {
        let build = |strategy: ExecStrategy| {
            PipelineBuilder::new()
                .spec(PipelineSpec::dlrm(m.range))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(32 * 1024)
                .strategy(strategy)
                .executor(backend.executor())
                .build()
                .expect("plan")
        };
        let fused_pipe = build(ExecStrategy::Fused);
        let two_pipe = build(ExecStrategy::TwoPass);

        // Correctness gate first: identical checksums.
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (fused_cols, _) = fused_pipe.run_collect(&mut src).expect("fused run");
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (two_cols, _) = two_pipe.run_collect(&mut src).expect("two-pass run");
        assert_eq!(
            checksum(&fused_cols),
            checksum(&two_cols),
            "{}: fused output must be bit-identical before timing",
            backend.name()
        );
        drop((fused_cols, two_cols));

        let time_of = |pipe: &piper::pipeline::Pipeline| {
            let mut wall = Vec::with_capacity(reps);
            let mut split = (std::time::Duration::ZERO, std::time::Duration::ZERO);
            for _ in 0..reps {
                let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                let mut sink = CountSink::new();
                let t0 = Instant::now();
                let report = pipe.run(&mut src, &mut sink).expect("submission");
                wall.push(t0.elapsed());
                split = (report.observe_time, report.process_time);
            }
            (median(wall), split)
        };
        let (fused_t, fused_split) = time_of(&fused_pipe);
        let (two_t, _) = time_of(&two_pipe);
        t.row(&[
            backend.name(),
            fmt_duration(two_t),
            fmt_duration(fused_t),
            fmt_speedup(two_t.as_secs_f64() / fused_t.as_secs_f64().max(1e-12)),
            format!("{} / {}", fmt_duration(fused_split.0), fmt_duration(fused_split.1)),
        ]);
    }
    t.note("checksums asserted identical; fused observe = sequential vocab stage");
    t.note("two-pass pays a second decode of the raw input — the saved pass is the win");
    t.print();
    println!();

    // ---- chunk-size sweep (CPU executor, the measured path) ------------
    let mut t = Table::new(
        "chunk-size sweep — CPU-4 Config I over the engine [meas]",
        &["chunk_rows", "chunks", "wallclock", "rows/s"],
    );
    for chunk_rows in [512usize, 4 * 1024, 32 * 1024, 256 * 1024] {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(chunk_rows)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
            .build()
            .expect("plan");
        let mut best = None;
        let mut chunks = 0;
        for _ in 0..reps {
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            let mut sink = CountSink::new();
            let t0 = Instant::now();
            let report = pipeline.run(&mut src, &mut sink).expect("submission");
            let d = t0.elapsed();
            chunks = report.chunks;
            best = Some(best.map_or(d, |b: std::time::Duration| b.min(d)));
        }
        let best = best.expect("reps >= 1");
        t.row(&[
            chunk_rows.to_string(),
            chunks.to_string(),
            fmt_duration(best),
            fmt_rows_per_sec(rows as f64 / best.as_secs_f64()),
        ]);
    }
    t.note("chunks = per-pass producer chunks; small chunks stress the bounded channel");
    t.print();
    println!();

    // ---- decode-threads sweep (row-sharded SWAR decode scaling) --------
    // The decode stage through the full engine: same plan, same
    // executor, only `decode_threads` varies. Outputs are
    // checksum-verified identical first; the table then reports the
    // engine's decode/execute wallclock split, whose decode side is the
    // ISSUE's ≥2×-at-4-threads acceptance gate. BENCH_JSON=path writes
    // the sweep machine-readably (scripts/bench_snapshot.sh).
    let mut t = Table::new(
        &format!("decode-threads sweep — UTF-8, {rows} rows, median of {reps} [meas]"),
        &["decode_threads", "decode", "rows/s (decode)", "wall", "speedup (decode)"],
    );
    let thread_sweep = [1usize, 2, 4, 8];
    let mut sweep_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    let mut want_sum = None;
    let mut base_decode = None;
    for &threads in &thread_sweep {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(64 * 1024)
            .decode_threads(threads)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 1 }.executor())
            .build()
            .expect("plan");
        // Correctness gate: decode_threads must not change one bit.
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, _) = pipeline.run_collect(&mut src).expect("sweep run");
        let sum = checksum(&cols);
        drop(cols);
        match want_sum {
            None => want_sum = Some(sum),
            Some(w) => assert_eq!(sum, w, "decode_threads={threads} changed the output"),
        }
        let mut decode_times = Vec::with_capacity(reps);
        let mut walls = Vec::with_capacity(reps);
        for _ in 0..reps {
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            let mut sink = CountSink::new();
            let t0 = Instant::now();
            let report = pipeline.run(&mut src, &mut sink).expect("sweep run");
            walls.push(t0.elapsed());
            decode_times.push(report.decode_time);
        }
        let decode = median(decode_times);
        let wall = median(walls);
        let decode_rps = rows as f64 / decode.as_secs_f64().max(1e-12);
        let base = *base_decode.get_or_insert(decode);
        t.row(&[
            threads.to_string(),
            fmt_duration(decode),
            fmt_rows_per_sec(decode_rps),
            fmt_duration(wall),
            fmt_speedup(base.as_secs_f64() / decode.as_secs_f64().max(1e-12)),
        ]);
        sweep_rows.push((threads, decode.as_secs_f64(), decode_rps, wall.as_secs_f64()));
    }
    t.note("row-sharded SWAR decode (decode/ shard module); checksums asserted identical");
    t.note("decode column = engine-measured wallclock inside the decode front");
    t.print();
    println!();

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut json = String::from("{\n  \"bench\": \"pipeline_engine/decode_threads_sweep\",\n");
        json.push_str(&format!("  \"rows\": {rows},\n  \"reps\": {reps},\n"));
        json.push_str(&format!(
            "  \"checksum\": \"{:#018x}\",\n  \"sweep\": [\n",
            want_sum.unwrap()
        ));
        for (i, (threads, decode_s, decode_rps, wall_s)) in sweep_rows.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"decode_threads\": {threads}, \"decode_s\": {decode_s:.6}, \
                 \"decode_rows_per_s\": {decode_rps:.0}, \"wall_s\": {wall_s:.6}}}{}\n",
                if i + 1 < sweep_rows.len() { "," } else { "" }
            ));
        }
        json.push_str("  ]\n}\n");
        std::fs::write(&path, json).expect("writing BENCH_JSON");
        println!("decode sweep written to {path}");
        println!();
    }

    // ---- per-column programs: uniform vs heterogeneous ------------------
    // The program-dispatch overhead question: does replacing the global
    // flag branches with per-column slot dispatch cost anything on a
    // uniform plan, and what does a genuinely heterogeneous plan (two
    // vocab sizes, partial dense log, one bucketized column) cost
    // relative to it? Same executor, same input, fused strategy. Each
    // pipeline is checksum-gated for run-to-run determinism before
    // timing. BENCH_PR5_JSON=path writes the rows machine-readably
    // (scripts/bench_snapshot.sh).
    let hetero_spec = "sparse[*]: modulus:5000|genvocab|applyvocab; \
                       sparse[0..4]: modulus:100000|genvocab|applyvocab; \
                       sparse[5]: modulus:53; \
                       dense[*]: neg2zero|logarithm; \
                       dense[0..3]: neg2zero; \
                       dense[12]: clip:0:100|bucketize:1:10:100";
    let mut t = Table::new(
        &format!("per-column programs — CPU-4 fused, UTF-8, {rows} rows, median of {reps} [meas]"),
        &["program set", "wallclock", "rows/s", "vs uniform"],
    );
    let mut pr5_rows: Vec<(&str, f64, f64)> = Vec::new();
    let mut uniform_wall: Option<std::time::Duration> = None;
    for (name, spec) in [
        ("uniform dlrm(5000)", PipelineSpec::dlrm(5000)),
        ("heterogeneous", PipelineSpec::parse(hetero_spec).expect("hetero spec parses")),
    ] {
        let pipeline = PipelineBuilder::new()
            .spec(spec)
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(32 * 1024)
            .strategy(ExecStrategy::Fused)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
            .build()
            .expect("plan");
        // Determinism gate: two collected runs must checksum equal.
        let sum_of = |pipe: &piper::pipeline::Pipeline| {
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            checksum(&pipe.run_collect(&mut src).expect("program run").0)
        };
        assert_eq!(sum_of(&pipeline), sum_of(&pipeline), "{name}: nondeterministic output");
        let wall = median(
            (0..reps)
                .map(|_| {
                    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                    let mut sink = CountSink::new();
                    let t0 = Instant::now();
                    pipeline.run(&mut src, &mut sink).expect("submission");
                    t0.elapsed()
                })
                .collect(),
        );
        let base = *uniform_wall.get_or_insert(wall);
        let ratio = wall.as_secs_f64() / base.as_secs_f64().max(1e-12);
        t.row(&[
            name.into(),
            fmt_duration(wall),
            fmt_rows_per_sec(rows as f64 / wall.as_secs_f64()),
            format!("{ratio:.2}×"),
        ]);
        pr5_rows.push((name, wall.as_secs_f64(), rows as f64 / wall.as_secs_f64()));
    }
    t.note("per-column dispatch replaces the old global OpFlags branches in both rows");
    t.note("heterogeneous = 2 vocab sizes + vocab-free col + partial log + bucketize col");
    t.print();
    println!();

    if let Ok(path) = std::env::var("BENCH_PR5_JSON") {
        let mut json = String::from("{\n  \"bench\": \"pipeline_engine/per_column_programs\",\n");
        json.push_str(&format!("  \"rows\": {rows},\n  \"reps\": {reps},\n  \"programs\": [\n"));
        for (i, (name, wall_s, rps)) in pr5_rows.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"program\": \"{name}\", \"wall_s\": {wall_s:.6}, \
                 \"rows_per_s\": {rps:.0}}}{}\n",
                if i + 1 < pr5_rows.len() { "," } else { "" }
            ));
        }
        json.push_str("  ]\n}\n");
        std::fs::write(&path, json).expect("writing BENCH_PR5_JSON");
        println!("per-column program rows written to {path}");
        println!();
    }

    // ---- stage-pipeline overlap sweep (fused, vocab-heavy) --------------
    // The stage-pipelined scheduler question: decode + stateless ops are
    // sharded, but the vocabulary scan is pinned sequential (appearance
    // order = determinism). Does running chunk N+1's frontend while
    // chunk N sits in the vocab stage push fused throughput toward the
    // slower stage's standalone rate? Grid: decode_threads ×
    // pipeline_depth on the vocab-heavy CPU fused plan, plus a two-pass
    // reference at the widest frontend. Every cell is checksum-gated
    // against the two-pass output before timing. BENCH_PR8_JSON=path
    // writes the grid machine-readably (scripts/bench_snapshot.sh).
    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
    let two_ref = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(m.range))
        .schema(ds.schema())
        .input(InputFormat::Utf8)
        .chunk_rows(32 * 1024)
        .strategy(ExecStrategy::TwoPass)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
        .build()
        .expect("plan");
    let want_sum = checksum(&two_ref.run_collect(&mut src).expect("two-pass reference").0);

    let mut t = Table::new(
        &format!("stage-pipeline overlap — CPU-4 fused, UTF-8, {rows} rows, median of {reps} [meas]"),
        &[
            "decode_threads",
            "depth",
            "wall",
            "rows/s",
            "stateless busy",
            "vocab busy",
            "vocab wait",
        ],
    );
    // (decode_threads, depth, wall_s, rows_per_s, stateless_s, vocab_busy_s, vocab_wait_s)
    let mut grid: Vec<(usize, usize, f64, f64, f64, f64, f64)> = Vec::new();
    for &threads in &[1usize, 4] {
        for &depth in &[1usize, 2, 4] {
            let pipeline = PipelineBuilder::new()
                .spec(PipelineSpec::dlrm(m.range))
                .schema(ds.schema())
                .input(InputFormat::Utf8)
                .chunk_rows(32 * 1024)
                .decode_threads(threads)
                .strategy(ExecStrategy::Fused)
                .pipeline_depth(depth)
                .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
                .build()
                .expect("plan");
            // Determinism gate: any depth must reproduce the two-pass
            // output bit for bit.
            let mut src = MemorySource::new(&raw, InputFormat::Utf8);
            let (cols, _) = pipeline.run_collect(&mut src).expect("overlap run");
            assert_eq!(
                checksum(&cols),
                want_sum,
                "decode_threads={threads} pipeline_depth={depth} changed the output"
            );
            drop(cols);
            let mut walls = Vec::with_capacity(reps);
            let mut split = (Duration::ZERO, Duration::ZERO, Duration::ZERO);
            for _ in 0..reps {
                let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                let mut sink = CountSink::new();
                let t0 = Instant::now();
                let report = pipeline.run(&mut src, &mut sink).expect("overlap run");
                walls.push(t0.elapsed());
                split = (report.stage_stateless_time, report.observe_time, report.vocab_wait_time);
            }
            let wall = median(walls);
            let rps = rows as f64 / wall.as_secs_f64().max(1e-12);
            t.row(&[
                threads.to_string(),
                depth.to_string(),
                fmt_duration(wall),
                fmt_rows_per_sec(rps),
                fmt_duration(split.0),
                fmt_duration(split.1),
                fmt_duration(split.2),
            ]);
            grid.push((
                threads,
                depth,
                wall.as_secs_f64(),
                rps,
                split.0.as_secs_f64(),
                split.1.as_secs_f64(),
                split.2.as_secs_f64(),
            ));
        }
    }
    let two_wall = median(
        (0..reps)
            .map(|_| {
                let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                let mut sink = CountSink::new();
                let t0 = Instant::now();
                two_ref.run(&mut src, &mut sink).expect("two-pass run");
                t0.elapsed()
            })
            .collect(),
    );
    // Overlap efficiency at the widest frontend: the depth-1 cell gives
    // the per-stage serial costs (frontend = wall − vocab busy); the
    // pipelined ideal is max(frontend, vocab), and efficiency is how
    // close the best depth>1 cell gets to it.
    let d1 = grid
        .iter()
        .find(|g| g.0 == 4 && g.1 == 1)
        .copied()
        .expect("depth-1 cell present");
    let vocab_s = d1.5;
    let frontend_s = (d1.2 - vocab_s).max(1e-12);
    let ideal_s = frontend_s.max(vocab_s);
    let best = grid
        .iter()
        .filter(|g| g.0 == 4 && g.1 > 1)
        .fold(f64::INFINITY, |acc, g| acc.min(g.2));
    let efficiency = ideal_s / best.max(1e-12);
    t.note("depth 1 = sequential chunk-at-a-time; depth N keeps N chunks in flight");
    t.note(&format!(
        "ideal wall (max stage, 4 threads) {:.3}s vs best pipelined {:.3}s — {:.0}% of ideal; two-pass {:.3}s",
        ideal_s,
        best,
        efficiency * 100.0,
        two_wall.as_secs_f64(),
    ));
    t.print();
    println!();

    if let Ok(path) = std::env::var("BENCH_PR8_JSON") {
        let mut json =
            String::from("{\n  \"bench\": \"pipeline_engine/stage_pipeline_overlap\",\n");
        json.push_str(&format!("  \"rows\": {rows},\n  \"reps\": {reps},\n"));
        json.push_str(&format!("  \"checksum\": \"{want_sum:#018x}\",\n  \"grid\": [\n"));
        for (i, (threads, depth, wall_s, rps, stateless_s, vocab_s, wait_s)) in
            grid.iter().enumerate()
        {
            json.push_str(&format!(
                "    {{\"decode_threads\": {threads}, \"pipeline_depth\": {depth}, \
                 \"wall_s\": {wall_s:.6}, \"rows_per_s\": {rps:.0}, \
                 \"stateless_s\": {stateless_s:.6}, \"vocab_busy_s\": {vocab_s:.6}, \
                 \"vocab_wait_s\": {wait_s:.6}}}{}\n",
                if i + 1 < grid.len() { "," } else { "" }
            ));
        }
        json.push_str("  ],\n");
        json.push_str(&format!(
            "  \"two_pass\": {{\"decode_threads\": 4, \"wall_s\": {:.6}, \"rows_per_s\": {:.0}}},\n",
            two_wall.as_secs_f64(),
            rows as f64 / two_wall.as_secs_f64().max(1e-12),
        ));
        json.push_str(&format!(
            "  \"overlap\": {{\"ideal_wall_s\": {ideal_s:.6}, \"best_wall_s\": {best:.6}, \
             \"efficiency\": {efficiency:.4}}}\n"
        ));
        json.push_str("}\n");
        std::fs::write(&path, json).expect("writing BENCH_PR8_JSON");
        println!("stage-pipeline overlap grid written to {path}");
        println!();
    }

    // ---- error-containment policy overhead (clean input) ----------------
    // The containment tax question: with zero malformed rows, what does
    // carrying an error policy cost? Same CPU fused plan, same clean
    // UTF-8 input; only `on_error` varies (quarantine also creates an
    // empty side file). Every policy is checksum-gated against the zero
    // baseline before timing. BENCH_PR9_JSON=path writes the rows
    // machine-readably; scripts/bench_compare.sh holds skip and fail
    // within 2% of zero and quarantine within 10%.
    let qpath =
        std::env::temp_dir().join(format!("piper-bench-qrn-{}.bin", std::process::id()));
    let mut t = Table::new(
        &format!(
            "containment policy overhead on clean input ({rows} rows, median of {reps}) [meas]"
        ),
        &["on_error", "wallclock", "rows/s", "vs zero"],
    );
    let mut pr9_rows: Vec<(&str, f64, f64)> = Vec::new();
    let mut pr9_sum: Option<u64> = None;
    let mut zero_wall: Option<Duration> = None;
    for policy in ["zero", "fail", "skip", "quarantine"] {
        let mut b = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(m.range))
            .schema(ds.schema())
            .input(InputFormat::Utf8)
            .chunk_rows(32 * 1024)
            .strategy(ExecStrategy::Fused)
            .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor());
        b = match policy {
            "quarantine" => b.quarantine(&qpath),
            _ => b.on_error(ErrorPolicy::parse(policy).expect("policy parses")),
        };
        let pipeline = b.build().expect("plan");
        // Correctness gate: clean input keeps every row, contains
        // nothing, and checksums identical under every policy.
        let mut src = MemorySource::new(&raw, InputFormat::Utf8);
        let (cols, report) = pipeline.run_collect(&mut src).expect("policy run");
        assert_eq!(report.rows, rows, "{policy}: clean input keeps every row");
        assert_eq!(report.row_errors.total, 0, "{policy}: clean input has no defects");
        let sum = checksum(&cols);
        drop(cols);
        match pr9_sum {
            None => pr9_sum = Some(sum),
            Some(w) => assert_eq!(sum, w, "{policy}: policy changed clean output"),
        }
        let wall = median(
            (0..reps)
                .map(|_| {
                    let mut src = MemorySource::new(&raw, InputFormat::Utf8);
                    let mut sink = CountSink::new();
                    let t0 = Instant::now();
                    pipeline.run(&mut src, &mut sink).expect("policy run");
                    t0.elapsed()
                })
                .collect(),
        );
        let base = *zero_wall.get_or_insert(wall);
        let ratio = wall.as_secs_f64() / base.as_secs_f64().max(1e-12);
        t.row(&[
            policy.into(),
            fmt_duration(wall),
            fmt_rows_per_sec(rows as f64 / wall.as_secs_f64()),
            format!("{ratio:.2}×"),
        ]);
        pr9_rows.push((policy, wall.as_secs_f64(), rows as f64 / wall.as_secs_f64()));
    }
    let _ = std::fs::remove_file(&qpath);
    t.note("CPU-4 fused, UTF-8; the policy branch is per defect, not per row");
    t.note("quarantine additionally creates (and here leaves empty) the side file");
    t.print();
    println!();

    if let Ok(path) = std::env::var("BENCH_PR9_JSON") {
        let mut json =
            String::from("{\n  \"bench\": \"pipeline_engine/containment_policy_overhead\",\n");
        json.push_str(&format!("  \"rows\": {rows},\n  \"reps\": {reps},\n"));
        json.push_str(&format!(
            "  \"checksum\": \"{:#018x}\",\n  \"policies\": [\n",
            pr9_sum.unwrap()
        ));
        for (i, (policy, wall_s, rps)) in pr9_rows.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"policy\": \"{policy}\", \"wall_s\": {wall_s:.6}, \
                 \"rows_per_s\": {rps:.0}}}{}\n",
                if i + 1 < pr9_rows.len() { "," } else { "" }
            ));
        }
        json.push_str("  ]\n}\n");
        std::fs::write(&path, json).expect("writing BENCH_PR9_JSON");
        println!("containment policy overhead written to {path}");
        println!();
    }

    // ---- generator-fed run: no materialized dataset anywhere -----------
    let gen_rows = rows.max(50_000);
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(m.range))
        .input(InputFormat::Utf8)
        .chunk_rows(32 * 1024)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 4 }.executor())
        .build()
        .expect("plan");
    let mut src = SynthSource::new(piper::data::SynthConfig::small(gen_rows), InputFormat::Utf8);
    let mut sink = CountSink::new();
    let t0 = Instant::now();
    let report = pipeline.run(&mut src, &mut sink).expect("generator run");
    let d = t0.elapsed();
    println!(
        "generator → engine → CountSink: {} rows in {} ({}), resident state = vocabularies + ~{} raw chunks",
        report.rows,
        fmt_duration(d),
        fmt_rows_per_sec(report.rows as f64 / d.as_secs_f64()),
        4,
    );
    println!();

    // ---- disaggregated service scale-out sweep (loopback) --------------
    // Real TCP loopback workers, one decode thread each, so the sweep
    // measures scale-out across workers rather than intra-worker
    // threading. Every cluster size is checksum-gated against the
    // single-worker output before any time is reported.
    // BENCH_PR10_JSON=path writes the rows machine-readably;
    // scripts/bench_compare.sh guards the 4-worker speedup ratio.
    let job = piper::net::protocol::Job::dlrm(ds.schema(), m, WireFormat::Utf8);
    let svc_cfg = ServiceConfig { decode_threads: 1, ..ServiceConfig::default() };
    let mut t = Table::new(
        &format!(
            "service scale-out — loopback workers ({rows} rows, median of {reps}) [meas wallclock]"
        ),
        &["workers", "wallclock", "rows/s", "vs 1 worker"],
    );
    let mut pr10_rows: Vec<(usize, f64, f64)> = Vec::new();
    let mut pr10_sum: Option<u64> = None;
    let mut one_worker: Option<Duration> = None;
    for n in [1usize, 2, 4] {
        // Correctness gate: every size produces the sequential answer.
        let run = run_service_loopback(n, &job, &raw, &svc_cfg).expect("service run");
        assert_eq!(run.stats.rows, rows as u64, "{n} workers: every row accounted for");
        assert_eq!((run.retries, run.faults), (0, 0), "{n} workers: clean loopback run");
        let sum = checksum(&run.processed);
        match pr10_sum {
            None => pr10_sum = Some(sum),
            Some(w) => assert_eq!(sum, w, "{n} workers changed the output"),
        }
        let wall = median(
            (0..reps)
                .map(|_| {
                    let t0 = Instant::now();
                    let r = run_service_loopback(n, &job, &raw, &svc_cfg).expect("service run");
                    let d = t0.elapsed();
                    assert_eq!(r.stats.rows, rows as u64);
                    d
                })
                .collect(),
        );
        let base = *one_worker.get_or_insert(wall);
        t.row(&[
            format!("{n}"),
            fmt_duration(wall),
            fmt_rows_per_sec(rows as f64 / wall.as_secs_f64()),
            fmt_speedup(base.as_secs_f64() / wall.as_secs_f64().max(1e-12)),
        ]);
        pr10_rows.push((n, wall.as_secs_f64(), rows as f64 / wall.as_secs_f64()));
    }
    t.note("real TCP loopback; timing includes worker spawn, join and teardown");
    t.note("vocabularies are shard-owned: no Pass1End -> VocabLoad barrier on the wire");
    t.print();
    println!();

    if let Ok(path) = std::env::var("BENCH_PR10_JSON") {
        let speedup4 = pr10_rows[0].1 / pr10_rows.last().unwrap().1.max(1e-12);
        let mut json = String::from("{\n  \"bench\": \"pipeline_engine/service_scaleout\",\n");
        json.push_str(&format!("  \"rows\": {rows},\n  \"reps\": {reps},\n"));
        json.push_str(&format!(
            "  \"checksum\": \"{:#018x}\",\n  \"sweep\": [\n",
            pr10_sum.unwrap()
        ));
        for (i, (workers, wall_s, rps)) in pr10_rows.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"workers\": {workers}, \"wall_s\": {wall_s:.6}, \
                 \"rows_per_s\": {rps:.0}}}{}\n",
                if i + 1 < pr10_rows.len() { "," } else { "" }
            ));
        }
        json.push_str(&format!("  ],\n  \"speedup4\": {speedup4:.3}\n}}\n"));
        std::fs::write(&path, json).expect("writing BENCH_PR10_JSON");
        println!("service scale-out sweep written to {path}");
        println!();
    }
}
