//! Ablations over PIPER's design choices (DESIGN.md experiment index):
//!
//!   A. decode width 1/2/4/8 — Script 1's parallel decode (functional
//!      measured + modeled cycles);
//!   B. vocabulary placement — SRAM vs HBM channel counts (modeled II);
//!   C. FIFO depth — producer/consumer stall behaviour under the bursty
//!      width-4 decoder (discrete simulation);
//!   D. number of parallel sparse dataflows — the U250 vs U55c gap.

use piper::accel::memory::VocabPlacement;
use piper::accel::{dataflow, fifo, InputFormat, Mode, PiperConfig};
use piper::benchutil::{bench_rows, dataset, paper};
use piper::data::utf8;
use piper::decode::ParallelDecoder;
use piper::ops::Modulus;
use piper::report::{fmt_duration, fmt_rows_per_sec, Table};
use std::time::Instant;

fn main() {
    let rows = bench_rows(100_000);
    let ds = dataset(rows);
    let raw = utf8::encode_dataset(&ds);

    // ---- A. decode width ------------------------------------------------
    let mut t = Table::new(
        "Ablation A — parallel decode width (Script 1)",
        &["width", "functional [meas]", "modeled cycles", "kernel rows/s @250MHz [sim]"],
    );
    for w in [1usize, 2, 4, 8] {
        let d = ParallelDecoder::with_width(ds.schema(), w);
        let t0 = Instant::now();
        let out = d.decode(&raw);
        let meas = t0.elapsed();
        // paper-scale kernel throughput when decode-bound (2 loops)
        let cpr = (paper::UTF8_BYTES as f64 / paper::ROWS as f64) / w as f64;
        let rps = 250.0e6 / (2.0 * cpr);
        t.row(&[
            w.to_string(),
            fmt_duration(meas),
            out.cycles.to_string(),
            fmt_rows_per_sec(rps),
        ]);
    }
    t.note("paper: width 4 lifts the decode-bound UTF-8 path ~4× over byte-at-a-time");
    t.print();
    println!();

    // ---- B. vocabulary placement ----------------------------------------
    let mut t = Table::new(
        "Ablation B — vocabulary placement (ApplyVocab effective II)",
        &["placement", "II", "loop-2 cycles/row", "kernel rows/s @135MHz [sim]"],
    );
    for (name, p) in [
        ("SRAM (on-chip)", VocabPlacement::Sram),
        ("HBM 1 channel", VocabPlacement::Hbm { latency: 15, channels: 1, sharers: 1 }),
        ("HBM 8 ch / 26 cols", VocabPlacement::Hbm { latency: 15, channels: 8, sharers: 26 }),
        ("HBM 32 ch / 26 cols (U55c)", VocabPlacement::hbm_u55c()),
        ("HBM 32 ch / 1 col", VocabPlacement::Hbm { latency: 15, channels: 32, sharers: 1 }),
    ] {
        let mut cfg = PiperConfig::paper(Mode::Network, InputFormat::Binary, Modulus::VOCAB_1M);
        cfg.vocab_placement = p;
        let k = dataflow::model_timing(&cfg, paper::BINARY_BYTES, paper::ROWS, 26 * 700_000);
        let rps = paper::ROWS as f64 / k.seconds().as_secs_f64();
        t.row(&[
            name.into(),
            format!("{:.1}", p.vocab_ii()),
            format!("{:.1}", k.loop2_cpr),
            fmt_rows_per_sec(rps),
        ]);
    }
    t.note("paper §4.4.6: round-robin across independent channels hides the ~15-cycle latency");
    t.print();
    println!();

    // ---- C. FIFO depth ---------------------------------------------------
    let mut t = Table::new(
        "Ablation C — inter-PE FIFO depth under the bursty ×4 decoder",
        &["depth", "producer stalls", "consumer starves", "cycles/token"],
    );
    for depth in [2usize, 4, 8, 16, 64] {
        let s = fifo::simulate(100_000, depth, 4, 1, 4);
        t.row(&[
            depth.to_string(),
            s.producer_stalls.to_string(),
            s.consumer_starves.to_string(),
            format!("{:.2}", s.total_cycles as f64 / 100_000.0),
        ]);
    }
    t.note("burst=4 (decoder emits 0–4 values/cycle); depth ≥ burst absorbs it");
    t.print();
    println!();

    // ---- D. parallel sparse dataflows -------------------------------------
    let mut t = Table::new(
        "Ablation D — parallel sparse dataflows (binary input, 5K vocab)",
        &["dataflows", "cols/flow", "loop cycles/row", "kernel rows/s @250MHz [sim]"],
    );
    for df in [2usize, 4, 8, 13, 26] {
        let mut cfg =
            PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Binary, Modulus::VOCAB_5K);
        cfg.sparse_dataflows = df;
        let k = dataflow::model_timing(&cfg, paper::BINARY_BYTES, paper::ROWS, 26 * 5_000);
        let rps = paper::ROWS as f64 / k.seconds().as_secs_f64();
        t.row(&[
            df.to_string(),
            ((26 + df - 1) / df).to_string(),
            format!("{:.1}", k.loop1_cpr + k.loop2_cpr),
            fmt_rows_per_sec(rps),
        ]);
    }
    t.note("the U250 build fits 8 flows, the U55c 13 — the local/network binary gap in Table 3");
    t.print();
}
