//! Figure 10 — PIPER local-mode time breakdown: Get Row Number /
//! Initialize Buffer / Assign Values / Kernel Execution, for
//! decode-in-kernel (Fig. 7b) vs decode-in-host (Fig. 7c).
//!
//! All values are model outputs at paper scale (tagged sim). Qualitative
//! checks against the paper:
//!   * Initialize Buffer occupies a large share in both modes;
//!   * decode-in-host execution ≈ 50% longer than decoding twice in the
//!     kernel;
//!   * these host costs are exactly what network mode deletes.

use piper::accel::{dataflow, host::HostModel, InputFormat, Mode, PiperConfig};
use piper::benchutil::paper;
use piper::ops::Modulus;
use piper::report::{fmt_duration, Table};

fn main() {
    let hm = HostModel::default();
    let uniq = 26 * 5_000;

    let mut t = Table::new(
        "Fig. 10 — PIPER local-mode breakdown at paper scale [all sim]",
        &["mode", "GetRowNum", "InitBuffer", "AssignValues", "KernelExec", "total"],
    );

    for (label, mode) in [
        ("Decode in Kernel", Mode::LocalDecodeInKernel),
        ("Decode in Host", Mode::LocalDecodeInHost),
    ] {
        let cfg = PiperConfig::paper(mode, InputFormat::Utf8, Modulus::VOCAB_5K);
        let kernel =
            dataflow::model_timing(&cfg, paper::UTF8_BYTES, paper::ROWS, uniq).seconds();
        let hb = hm.local_breakdown(&cfg, paper::UTF8_BYTES, paper::ROWS, kernel);
        t.row(&[
            label.into(),
            fmt_duration(hb.get_row_number),
            fmt_duration(hb.initialize_buffer),
            fmt_duration(hb.assign_values),
            fmt_duration(hb.kernel_execution),
            fmt_duration(hb.total()),
        ]);
        let shares = hb.shares();
        t.note(&format!(
            "{label}: shares {}",
            shares
                .iter()
                .map(|(n, s)| format!("{n} {:.0}%", s * 100.0))
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }
    t.note("paper: InitBuffer dominates; host-decode ≈1.5× of double kernel-decode");
    t.print();

    // The §4.4.3 check: host decode shrinks kernel time but loses e2e.
    let ck = PiperConfig::paper(Mode::LocalDecodeInKernel, InputFormat::Utf8, Modulus::VOCAB_5K);
    let ch = PiperConfig::paper(Mode::LocalDecodeInHost, InputFormat::Utf8, Modulus::VOCAB_5K);
    let kk = dataflow::model_timing(&ck, paper::UTF8_BYTES, paper::ROWS, uniq).seconds();
    let kh = dataflow::model_timing(&ch, paper::UTF8_BYTES, paper::ROWS, uniq).seconds();
    println!(
        "\nkernel-only: decode-in-kernel {} vs decode-in-host {} (kernel shrinks {:.1}×)",
        fmt_duration(kk),
        fmt_duration(kh),
        kk.as_secs_f64() / kh.as_secs_f64()
    );
}
