//! Figure 8 — CPU baseline: Configs I/II/III across thread counts with
//! the four-stage breakdown, for vocab 5K (8a) and 1M (8b).
//!
//! Protocol: the single-thread work components (parse, vocabulary
//! observe, sub-dict merge, apply, concat) are MEASURED on this machine,
//! then projected to the paper's 128-core EPYC at paper scale (46M rows)
//! by the calibrated Amdahl model in `cpu_baseline::scaling` — this box
//! may have fewer cores than the paper's server (possibly one), so
//! multi-thread points cannot be measured directly. T=1 components are
//! measured; every projected cell is tagged sim.
//!
//! The paper's qualitative findings to check:
//!   * performance does not scale linearly with threads;
//!   * GV/AV saturate around 32–64 threads (sub-dict merge + bandwidth);
//!   * Config II degrades beyond 32 threads (shared locked dictionary);
//!   * Concatenate grows with thread count; SIF stays constant.

use piper::benchutil::{bench_rows, dataset, paper};
use piper::cpu_baseline::{
    profile_single_thread, project, BaselineConfig, ConfigKind, ServerModel, SimDisk,
};
use piper::data::{binary, utf8};
use piper::ops::Modulus;
use piper::report::{fmt_duration, Table};

fn main() {
    let rows = bench_rows(150_000);
    let ds = dataset(rows);
    let raw_utf8 = utf8::encode_dataset(&ds);
    let raw_bin = binary::encode_dataset(&ds);
    let threads = [1usize, 8, 16, 32, 64, 128];
    let server = ServerModel::paper_epyc();
    let disk = SimDisk::default();

    for (vocab, fig) in [(Modulus::VOCAB_5K, "8a"), (Modulus::VOCAB_1M, "8b")] {
        let mut t = Table::new(
            &format!(
                "Fig. {fig} — CPU baseline @46M rows, vocab {} (profiled over {rows} rows [meas], projected to 128-core EPYC [sim])",
                vocab.range
            ),
            &["config", "threads", "SIF", "GenVocab", "ApplyVocab", "Concat", "total"],
        );
        for kind in [ConfigKind::I, ConfigKind::II, ConfigKind::III] {
            let raw: &[u8] = if kind.binary_input() { &raw_bin } else { &raw_utf8 };
            let cfg = BaselineConfig::new(kind, 1, vocab);
            let profile = profile_single_thread(&cfg, raw).scaled_to(paper::ROWS);
            let mut best: Option<(usize, std::time::Duration)> = None;
            for &n in &threads {
                let times = project(&profile, kind, n, &disk, &server, false);
                let total = times.total();
                if best.map_or(true, |(_, b)| total < b) {
                    best = Some((n, total));
                }
                t.row(&[
                    kind.name().into(),
                    n.to_string(),
                    fmt_duration(times.sif.total()),
                    fmt_duration(times.gen_vocab.total()),
                    fmt_duration(times.apply_vocab.total()),
                    fmt_duration(times.concat.total()),
                    fmt_duration(total),
                ]);
            }
            if let Some((n, d)) = best {
                t.note(&format!("{} best: {} threads ({})", kind.name(), n, fmt_duration(d)));
            }
        }
        t.note("paper: Config I best @64t (5K) / @32t (1M); II best @32t (5K) / @16t (1M); III best @32t");
        t.print();
        println!();
    }
}
