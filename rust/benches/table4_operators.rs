//! Table 4 — per-operator execution time over the whole dataset:
//! CPU single-thread (measured here, scaled to the paper's 46M rows) vs
//! the FPGA PE model at 250 MHz (5K build) / 135 MHz (1M build).
//!
//! Paper values are printed alongside. The FPGA's per-operator time is
//! II × items / f_clk over 1.83e9 feature values (46M rows × 40 values),
//! exactly how the paper's 7.33 s / 13.58 s "II=1" constants arise.

use std::time::{Duration, Instant};

use piper::accel::memory::VocabPlacement;
use piper::accel::pe::PeKind;
use piper::benchutil::{bench_rows, dataset, paper};
use piper::data::{binary, utf8};
use piper::decode::ScalarDecoder;
use piper::ops::{self, hex::hex2int, DirectVocab, Modulus, Vocab};
use piper::report::{fmt_duration, Table};

/// Measure `f` and scale the per-item cost to `paper_items`.
fn measure_scaled<F: FnMut()>(mut f: F, items: usize, paper_items: usize) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed().mul_f64(paper_items as f64 / items.max(1) as f64)
}

fn main() {
    let rows = bench_rows(80_000);
    let ds = dataset(rows);
    let raw_utf8 = utf8::encode_dataset(&ds);
    let raw_bin = binary::encode_dataset(&ds);
    let all_values = paper::ROWS * 40; // 1.83e9 — the paper's item count
    let sparse_vals = paper::ROWS * 26;

    for (vocab, clock) in [(Modulus::VOCAB_5K, 250.0e6), (Modulus::VOCAB_1M, 135.0e6)] {
        let placement = if vocab.range > 100_000 {
            VocabPlacement::hbm_u55c()
        } else {
            VocabPlacement::Sram
        };
        let fpga = |pe: PeKind, items: usize| {
            // Table 4 uses HBM round-robin II=1 for ApplyVocab (§4.4.6).
            let ii = match pe {
                PeKind::ApplyVocab1 | PeKind::ApplyVocab2 if vocab.range > 100_000 => 1.0,
                _ => pe.ii(placement),
            };
            fmt_duration(Duration::from_secs_f64(ii * items as f64 / clock))
        };

        // --- CPU measurements (single thread), scaled ------------------
        let mut sparse: Vec<u32> = ds.rows.iter().flat_map(|r| r.sparse.clone()).collect();
        let dense: Vec<i32> = ds.rows.iter().flat_map(|r| r.dense.clone()).collect();
        let hex_fields: Vec<Vec<u8>> = sparse
            .iter()
            .map(|v| format!("{v:08x}").into_bytes())
            .collect();

        let dec = ScalarDecoder::new(ds.schema());
        let t_decode =
            measure_scaled(|| { std::hint::black_box(dec.decode(&raw_utf8)); },
                raw_utf8.len(), paper::UTF8_BYTES);
        let t_unpack = measure_scaled(
            || { std::hint::black_box(binary::decode_bytes(&raw_bin, ds.schema()).unwrap()); },
            raw_bin.len(), paper::BINARY_BYTES);
        let mut acc = 0u64;
        let t_hexmod = measure_scaled(
            || {
                for f in &hex_fields {
                    acc = acc.wrapping_add(vocab.apply(hex2int(f).unwrap_or(0)) as u64);
                }
            },
            hex_fields.len(), sparse_vals);
        vocab.apply_slice(&mut sparse);
        let mut gv = DirectVocab::new(vocab.range);
        let t_genvocab = measure_scaled(
            || { for &v in &sparse { gv.observe(v); } }, sparse.len(), sparse_vals);
        let uniques: Vec<u32> = (0..gv.len() as u32).collect();
        let t_av1 = measure_scaled(
            || {
                let mut v2 = DirectVocab::new(vocab.range);
                for &u in &uniques { v2.observe(u); }
                std::hint::black_box(&v2);
            },
            uniques.len().max(1), gv.len().max(1) * 26 / 26);
        let mut applied = vec![0u32; sparse.len()];
        let t_av2 = measure_scaled(
            || gv.apply_slice(&sparse, &mut applied), sparse.len(), sparse_vals);
        let mut d2 = dense.clone();
        let t_n2z = measure_scaled(|| ops::neg2zero_slice(&mut d2), dense.len(),
            paper::ROWS * 13);
        let mut logs = Vec::new();
        let t_log = measure_scaled(|| ops::dense_finish_slice(&d2, &mut logs), dense.len(),
            paper::ROWS * 13);

        let mut t = Table::new(
            &format!(
                "Table 4 — per-operator seconds over whole dataset, vocab {} (FPGA @{:.0} MHz)",
                vocab.range, clock / 1e6
            ),
            &["operator", "CPU 1t [meas→scaled]", "FPGA [sim]", "paper CPU", "paper FPGA"],
        );
        let paper_cpu_gen = if vocab.range == 5_000 { "365.34s" } else { "410.82s" };
        let paper_av2 = if vocab.range == 5_000 { "331.79s" } else { "367.11s" };
        let paper_f = |s5: &str, s1m: &str| if vocab.range == 5_000 { s5.to_string() } else { s1m.to_string() };
        t.row(&["Decode & FillMissing".into(), fmt_duration(t_decode),
            fpga(PeKind::Decode, paper::UTF8_BYTES / 4), "182.29s".into(), paper_f("11.00s", "20.37s")]);
        t.row(&["Binary Unpack".into(), fmt_duration(t_unpack),
            fpga(PeKind::LoadData, all_values), "35.77s".into(), paper_f("7.33s", "13.58s")]);
        t.row(&["Hex2Int & Modulus".into(), fmt_duration(t_hexmod),
            fpga(PeKind::Modulus, all_values), "655.17s".into(), paper_f("7.33s", "13.58s")]);
        t.row(&["GenVocab-1".into(), fmt_duration(t_genvocab),
            fpga(PeKind::GenVocab1, all_values), paper_cpu_gen.into(), paper_f("14.67s", "27.16s")]);
        t.row(&["GenVocab-2".into(), "NOP".into(),
            fpga(PeKind::GenVocab2, all_values), "NOP".into(), paper_f("7.33s", "13.58s")]);
        t.row(&["ApplyVocab-1".into(), fmt_duration(t_av1),
            fpga(PeKind::ApplyVocab1, all_values),
            paper_f("0.0065s", "0.74s"), paper_f("7.33s", "13.58s")]);
        t.row(&["ApplyVocab-2".into(), fmt_duration(t_av2),
            fpga(PeKind::ApplyVocab2, all_values), paper_av2.into(), paper_f("7.33s", "13.58s")]);
        t.row(&["Neg2Zero".into(), fmt_duration(t_n2z),
            fpga(PeKind::Neg2Zero, all_values), "0.61s".into(), paper_f("7.33s", "13.58s")]);
        t.row(&["Logarithm".into(), fmt_duration(t_log),
            fpga(PeKind::Logarithm, all_values), "1.34s".into(), paper_f("7.33s", "13.58s")]);
        t.note("CPU column: this machine, single thread, scaled to 46M rows (absolute ≠ paper's EPYC)");
        t.note("shape check: Hex2Int & GenVocab dominate CPU; FPGA is flat II×items/f_clk");
        t.print();
        println!();
        std::hint::black_box((acc, applied, logs));
    }
}
