//! Figure 1 — preprocessing vs training time for one epoch at different
//! batch sizes.
//!
//! The paper's motivating gap: on a V100 + 12 vCPU box, preprocessing an
//! epoch takes several times longer than training it, at every batch
//! size. Here both sides run on this machine: preprocessing is the
//! measured CPU baseline; training is the AOT DLRM through PJRT at batch
//! sizes {128, 256, 512, 1024} (each its own artifact — lowered by
//! `make artifacts`). Requires artifacts; exits cleanly if missing.

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("fig1: built without the `pjrt` feature — rebuild with --features pjrt");
}

#[cfg(feature = "pjrt")]
fn main() {
    use std::path::Path;
    use std::time::Instant;

    use piper::benchutil::{bench_rows, dataset};
    use piper::cpu_baseline::{run as cpu_run, BaselineConfig, ConfigKind};
    use piper::data::utf8;
    use piper::ops::Modulus;
    use piper::report::{fmt_duration, Table};
    use piper::runtime::Runtime;
    use piper::train::{BatchIter, Trainer};

    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("train_step.hlo.txt").exists() {
        eprintln!("fig1: artifacts missing — run `make artifacts` first");
        return;
    }
    let rows = bench_rows(8_192);
    let ds = dataset(rows);
    let raw = utf8::encode_dataset(&ds);

    // Preprocessing: measured CPU baseline (Config II, 8 threads — the
    // paper's cloud-class host). Also a python-cost projection: the
    // paper's pipeline is Meta's Python implementation on 12 vCPUs,
    // whose measured throughput (paper Table 3, Config II @8t ≈ 2.3e5
    // rows/s) we apply to the same row count for a like-for-like ratio.
    let t0 = Instant::now();
    let pre = cpu_run(&BaselineConfig::new(ConfigKind::II, 8, Modulus::VOCAB_5K), &raw);
    let preprocess = t0.elapsed();
    // Supply rates (rows/s the preprocessing side can deliver):
    let supply_rust = rows as f64 / preprocess.as_secs_f64();
    // the paper's stack on its Fig.-1 host (Meta python pipeline,
    // 12 vCPUs ≈ Table 3 Config I @8t):
    let supply_python = 1.32e5f64;
    // Demand rate: a V100 training this DLRM class is embedding-gather /
    // HBM bound at roughly 3M samples/s regardless of batch size
    // (calibration note in EXPERIMENTS.md §Fig.1).
    let demand_v100 = 3.0e6f64;

    let rt = Runtime::new(&artifacts).expect("PJRT client");
    let mut t = Table::new(
        &format!("Fig. 1 — preprocessing supply vs training demand, {rows} rows"),
        &[
            "batch",
            "train 1 epoch here [meas]",
            "demand V100 [sim]",
            "supply rust-CPU [meas]",
            "supply python-CPU [sim]",
            "GPU util (python supply)",
        ],
    );

    for batch in [128usize, 256, 512, 1024] {
        let suffix = if batch == 256 { String::new() } else { format!("_b{batch}") };
        let mut trainer = match Trainer::with_suffix(&rt, &artifacts, &suffix) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("fig1: skipping batch {batch}: {e}");
                continue;
            }
        };
        let mut iter = BatchIter::new(&pre.processed, batch, 26).expect("batch iter");
        let steps = iter.batches_per_epoch();
        let t0 = Instant::now();
        for _ in 0..steps {
            let b = iter.next_batch();
            trainer.step(&b).expect("train step");
        }
        let train = t0.elapsed();
        let util = (supply_python / demand_v100 * 100.0).min(100.0);
        t.row(&[
            batch.to_string(),
            format!("{} ({steps} steps)", fmt_duration(train)),
            format!("{:.1}M rows/s", demand_v100 / 1e6),
            format!("{:.2}M rows/s", supply_rust / 1e6),
            format!("{:.2}M rows/s", supply_python / 1e6),
            format!("{util:.0}%"),
        ]);
    }
    t.note("paper Fig. 1: preprocessing cannot keep the GPU fed (util ≤40%, Meta reports 56% idle)");
    t.note("reproduced as supply < demand: the python pipeline feeds ≈4% of what a V100 consumes;");
    t.note("even this repo's rust pipeline on one core supplies <15% — preprocessing IS the bottleneck");
    t.note("train-epoch column is the real PJRT run on this box (functional proof, not a V100 proxy)");
    t.print();
}
