//! Serving-latency bench: request/response preprocessing against a
//! frozen vocabulary artifact over loopback TCP, latency percentiles vs
//! batch size.
//!
//! What to look for:
//!   * per-request latency is flat and small for serving-sized batches
//!     (1..512 rows) — the fixed cost is one frame round trip plus one
//!     `ChunkDecoder` scan, not a pipeline spin-up;
//!   * throughput grows with batch size as the per-frame overhead
//!     amortizes — the batch-size knob trades tail latency for rows/s;
//!   * every response is checked bit-identical to the local
//!     `FrozenPlan::apply_block` on the same bytes before any time is
//!     reported, so the numbers are for the *correct* fast path.

use std::time::{Duration, Instant};

use piper::benchutil::{bench_reps, bench_rows, dataset};
use piper::data::{binary, RowBlock};
use piper::net::{self, serve::MAX_REQUEST_BYTES, ServeJob, ServeStatus};
use piper::net::{protocol, stream::WireFormat};
use piper::ops::{PipelineSpec, VocabArtifact};
use piper::pipeline::{ChunkDecoder, FrozenPlan, MissPolicy};
use piper::report::{fmt_duration, fmt_rows_per_sec, Table};

fn percentile(sorted: &[Duration], p: usize) -> Duration {
    sorted[(sorted.len() - 1) * p / 100]
}

fn main() {
    let rows = bench_rows(100_000);
    let reqs_per_size = bench_reps(3) * 16;
    let ds = dataset(rows);
    let schema = ds.schema();
    let raw = binary::encode_dataset(&ds);
    let row_bytes = schema.binary_row_bytes();
    let spec = PipelineSpec::dlrm(5000);

    // Freeze: one GenVocab pass over the dataset, exported into the
    // artifact the worker will serve.
    let plans = spec.compile(schema).expect("spec compiles");
    let mut state = piper::pipeline::ChunkState::with_programs(plans);
    let mut block = RowBlock::new(schema);
    let mut dec = ChunkDecoder::new(piper::accel::InputFormat::Binary, schema);
    dec.feed_into(&raw, &mut block).expect("decode");
    dec.finish_into(&mut block).expect("decode end");
    state.observe(&block);
    let artifact = VocabArtifact::new(
        spec.clone(),
        schema,
        state.vocabs.iter().map(|v| v.export_keys()).collect(),
    )
    .expect("artifact");
    println!(
        "artifact: {} vocabulary entries across {} columns, {} request rows available",
        artifact.total_entries(),
        artifact.vocabs().len(),
        rows,
    );

    // Local reference for the equivalence gate.
    let frozen = FrozenPlan::from_artifact(&artifact, MissPolicy::Sentinel).expect("freeze");

    // Loopback worker, one serving session.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let server = std::thread::spawn(move || net::serve_one(&listener));

    let job = ServeJob {
        policy: MissPolicy::Sentinel,
        format: WireFormat::Binary,
        queue_depth: 32,
        artifact,
    };
    let mut client = net::ServeClient::connect(&addr, &job).expect("connect");

    let mut t = Table::new(
        &format!("serving latency — loopback TCP, binary, {reqs_per_size} requests per size [meas]"),
        &["batch rows", "p50", "p99", "rows/s"],
    );
    let mut scratch = RowBlock::new(schema);
    for batch in [1usize, 8, 32, 128, 512] {
        if batch > rows {
            continue; // tiny PIPER_BENCH_ROWS runs skip oversized batches
        }
        let bytes = batch * row_bytes;
        assert!(bytes <= MAX_REQUEST_BYTES, "bench batch under the serving cap");
        // Warm up the path (connection buffers, decoder allocation).
        for _ in 0..3 {
            client.request(&raw[..bytes]).expect("warmup");
        }
        let mut lat = Vec::with_capacity(reqs_per_size);
        for i in 0..reqs_per_size {
            // Slide through the dataset so requests vary (and stay
            // row-aligned: binary rows are fixed width).
            let off = (i * bytes) % (raw.len() - bytes + 1);
            let off = off - off % row_bytes;
            let req = &raw[off..off + bytes];
            let t0 = Instant::now();
            let resp = client.request(req).expect("request");
            lat.push(t0.elapsed());
            assert_eq!(resp.status, ServeStatus::Ok, "vocab built from these rows");
            // Equivalence gate: response bytes == local frozen apply.
            scratch.clear();
            let mut dec = ChunkDecoder::new(piper::accel::InputFormat::Binary, schema);
            dec.feed_into(req, &mut scratch).expect("local decode");
            dec.finish_into(&mut scratch).expect("local decode end");
            let local = frozen.apply_block(&scratch);
            assert_eq!(
                resp.payload,
                protocol::pack_columns(&local.columns, schema),
                "batch {batch}: served bytes must equal the local frozen apply"
            );
        }
        lat.sort_unstable();
        let p50 = percentile(&lat, 50);
        let p99 = percentile(&lat, 99);
        let total: Duration = lat.iter().sum();
        t.row(&[
            batch.to_string(),
            fmt_duration(p50),
            fmt_duration(p99),
            fmt_rows_per_sec((batch * reqs_per_size) as f64 / total.as_secs_f64().max(1e-12)),
        ]);
    }
    t.note("every response asserted bit-identical to FrozenPlan::apply_block locally");
    t.note("latency is client-measured round trip: send → decode → apply → pack → recv");
    t.print();
    println!();

    let (report, late) = client.finish().expect("finish");
    assert!(late.is_empty(), "all responses were consumed in-loop");
    let stats = server.join().expect("server thread").expect("serve_one");
    println!(
        "worker report: {} requests ({} ok), {} rows, {} misses; server-side p50 {} / p99 {}",
        report.requests,
        report.ok,
        report.rows,
        report.misses,
        fmt_duration(report.p50()),
        fmt_duration(report.p99()),
    );
    println!("worker session stats: {} rows, {} vocab entries", stats.rows, stats.vocab_entries);
}
