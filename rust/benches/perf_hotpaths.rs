//! Hot-path profiling harness (EXPERIMENTS.md §Perf): throughput of the
//! L3 request-path kernels in MB/s / Mrows/s, for before/after
//! comparisons during the optimization pass.
//!
//!   decode-scalar       byte state machine (Fig. 6)
//!   decode-parallel     Script-1 fold
//!   utf8-parse          baseline GV parse (split + hex2int + modulus)
//!   binary-unpack       Config III unpack
//!   genvocab-hash       HashVocab observe stream
//!   genvocab-direct     DirectVocab observe stream
//!   applyvocab          DirectVocab apply stream
//!   dense-finish        neg2zero + log1p
//!   tcp-loopback        end-to-end streaming worker

use std::time::Instant;

use piper::benchutil::{bench_reps, bench_rows, dataset, median};
use piper::cpu_baseline::{profile_single_thread, BaselineConfig, ConfigKind};
use piper::data::{binary, utf8};
use piper::decode::{ParallelDecoder, ScalarDecoder};
use piper::net::{leader, protocol::Job, stream::WireFormat};
use piper::ops::{self, DirectVocab, HashVocab, Modulus, Vocab};
use piper::report::Table;

fn time<F: FnMut()>(reps: usize, mut f: F) -> std::time::Duration {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    median(samples)
}

fn main() {
    let rows = bench_rows(200_000);
    let reps = bench_reps(5);
    let ds = dataset(rows);
    let raw_utf8 = utf8::encode_dataset(&ds);
    let raw_bin = binary::encode_dataset(&ds);
    let m = Modulus::VOCAB_5K;
    let sparse: Vec<u32> = ds
        .rows
        .iter()
        .flat_map(|r| r.sparse.iter().map(|&v| m.apply(v)))
        .collect();
    let dense: Vec<i32> = ds.rows.iter().flat_map(|r| r.dense.clone()).collect();

    let mut t = Table::new(
        &format!("hot paths ({rows} rows, median of {reps}) [all meas]"),
        &["path", "time", "throughput"],
    );
    let mut row = |name: &str, d: std::time::Duration, bytes: Option<usize>, items: usize| {
        let tput = match bytes {
            Some(b) => format!("{:.0} MB/s", b as f64 / d.as_secs_f64() / 1e6),
            None => format!("{:.1} Mitems/s", items as f64 / d.as_secs_f64() / 1e6),
        };
        t.row(&[name.into(), piper::report::fmt_duration(d), tput]);
    };

    let sd = ScalarDecoder::new(ds.schema());
    row("decode-scalar", time(reps, || { std::hint::black_box(sd.decode(&raw_utf8)); }),
        Some(raw_utf8.len()), rows);
    let pd = ParallelDecoder::new(ds.schema());
    row("decode-parallel", time(reps, || { std::hint::black_box(pd.decode(&raw_utf8)); }),
        Some(raw_utf8.len()), rows);

    let cfg = BaselineConfig::new(ConfigKind::I, 1, m);
    let d = time(reps.min(3), || {
        std::hint::black_box(profile_single_thread(&cfg, &raw_utf8).gv_parse);
    });
    row("utf8-parse (profile)", d, Some(raw_utf8.len()), rows);

    row("binary-unpack",
        time(reps, || { std::hint::black_box(binary::decode_bytes(&raw_bin, ds.schema()).unwrap()); }),
        Some(raw_bin.len()), rows);

    row("genvocab-hash", time(reps, || {
            let mut v = HashVocab::new();
            v.observe_slice(&sparse);
            std::hint::black_box(v.len());
        }), None, sparse.len());
    row("genvocab-direct", time(reps, || {
            let mut v = DirectVocab::new(m.range);
            v.observe_slice(&sparse);
            std::hint::black_box(v.len());
        }), None, sparse.len());

    let mut dv = DirectVocab::new(m.range);
    dv.observe_slice(&sparse);
    row("applyvocab", time(reps, || {
            let mut out = vec![0u32; sparse.len()];
            dv.apply_slice(&sparse, &mut out);
            std::hint::black_box(out.len());
        }), None, sparse.len());

    row("dense-finish", time(reps, || {
            let mut out = Vec::new();
            ops::dense_finish_slice(&dense, &mut out);
            std::hint::black_box(out.len());
        }), None, dense.len());

    let job = Job::dlrm(ds.schema(), m, WireFormat::Utf8);
    // run_loopback is fused: the dataset crosses the wire once.
    row("tcp-loopback e2e", time(3, || {
            std::hint::black_box(leader::run_loopback(&job, &raw_utf8, 1 << 20).unwrap().stats);
        }), Some(raw_utf8.len()), rows);

    // The streaming engine end to end (planned once, CountSink output).
    let pipeline = piper::pipeline::PipelineBuilder::new()
        .spec(piper::ops::PipelineSpec::dlrm(m.range))
        .schema(ds.schema())
        .input(piper::accel::InputFormat::Utf8)
        .chunk_rows(32 * 1024)
        .executor(Box::new(piper::cpu_baseline::CpuExecutor::new(ConfigKind::I, 1)))
        .build()
        .expect("plan");
    row("pipeline-engine e2e (1t)", time(3, || {
            let mut src = piper::pipeline::MemorySource::new(
                &raw_utf8,
                piper::accel::InputFormat::Utf8,
            );
            let mut sink = piper::pipeline::CountSink::new();
            std::hint::black_box(pipeline.run(&mut src, &mut sink).unwrap().rows);
        }), Some(raw_utf8.len() * 2), rows);

    t.print();
}
