//! Operator lab: every Table-1 operator exercised one by one, with the
//! CPU-measured cost and the FPGA PE model side by side — a miniature of
//! the paper's Table 4 you can poke at.
//!
//!     cargo run --release --example operator_lab

use std::time::{Duration, Instant};

use piper::accel::memory::VocabPlacement;
use piper::accel::pe::PeKind;
use piper::data::{synth::SynthConfig, utf8, SynthDataset};
use piper::decode::{ParallelDecoder, ScalarDecoder};
use piper::ops::{self, hex::hex2int, DirectVocab, Modulus, Vocab};
use piper::report::{fmt_duration, Table};

fn pe_time(pe: PeKind, items: u64, clock: f64) -> String {
    let secs = pe.stream_cycles(items, VocabPlacement::Sram) / clock;
    fmt_duration(Duration::from_secs_f64(secs))
}

fn main() {
    let rows = 50_000;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    let m = Modulus::VOCAB_5K;
    let clock = 250.0e6;
    let sparse_items = (rows * 26) as u64;
    let dense_items = (rows * 13) as u64;

    let mut t = Table::new(
        &format!("operator lab ({rows} rows)"),
        &["operator", "CPU measured", "FPGA model [sim]", "notes"],
    );

    // Decode: scalar vs parallel (Script 1)
    let t0 = Instant::now();
    let s = ScalarDecoder::new(ds.schema()).decode(&raw);
    let scalar_t = t0.elapsed();
    let t0 = Instant::now();
    let p = ParallelDecoder::new(ds.schema()).decode(&raw);
    let par_t = t0.elapsed();
    assert_eq!(s.rows, p.rows);
    t.row(&[
        "Decode (scalar, Fig.6)".into(),
        fmt_duration(scalar_t),
        fmt_duration(Duration::from_secs_f64(s.cycles as f64 / clock)),
        format!("{} B, 1 B/cycle", raw.len()),
    ]);
    t.row(&[
        "Decode (Script-1 ×4)".into(),
        fmt_duration(par_t),
        fmt_duration(Duration::from_secs_f64(p.cycles as f64 / clock)),
        "4 B/cycle, bit-exact vs scalar".into(),
    ]);

    // Hex2Int — a real cost on the CPU, merged into Decode on the FPGA.
    let fields: Vec<Vec<u8>> = ds
        .rows
        .iter()
        .flat_map(|r| r.sparse.iter().map(|v| format!("{v:08x}").into_bytes()))
        .collect();
    let t0 = Instant::now();
    let mut acc = 0u64;
    for f in &fields {
        acc = acc.wrapping_add(hex2int(f).unwrap_or(0) as u64);
    }
    t.row(&[
        "Hex2Int".into(),
        fmt_duration(t0.elapsed()),
        "0 (merged into Decode)".into(),
        format!("checksum {:x}", acc & 0xffff),
    ]);

    // Modulus / Neg2Zero / Logarithm
    let mut sparse: Vec<u32> = ds.rows.iter().flat_map(|r| r.sparse.clone()).collect();
    let t0 = Instant::now();
    m.apply_slice(&mut sparse);
    t.row(&[
        "Modulus".into(),
        fmt_duration(t0.elapsed()),
        pe_time(PeKind::Modulus, sparse_items, clock),
        format!("range {}", m.range),
    ]);

    let mut dense: Vec<i32> = ds.rows.iter().flat_map(|r| r.dense.clone()).collect();
    let t0 = Instant::now();
    ops::neg2zero_slice(&mut dense);
    t.row(&[
        "Neg2Zero".into(),
        fmt_duration(t0.elapsed()),
        pe_time(PeKind::Neg2Zero, dense_items, clock),
        "ternary".into(),
    ]);

    let t0 = Instant::now();
    let mut logs = Vec::new();
    ops::dense_finish_slice(&dense, &mut logs);
    t.row(&[
        "Logarithm".into(),
        fmt_duration(t0.elapsed()),
        pe_time(PeKind::Logarithm, dense_items, clock),
        "log(x+1)".into(),
    ]);

    // GenVocab + ApplyVocab — the stateful pair.
    let t0 = Instant::now();
    let mut vocab = DirectVocab::new(m.range);
    for &v in &sparse {
        vocab.observe(v);
    }
    t.row(&[
        "GenVocab".into(),
        fmt_duration(t0.elapsed()),
        pe_time(PeKind::GenVocab1, sparse_items, clock),
        format!("{} uniques", vocab.len()),
    ]);

    let t0 = Instant::now();
    let mut out = vec![0u32; sparse.len()];
    vocab.apply_slice(&sparse, &mut out);
    t.row(&[
        "ApplyVocab".into(),
        fmt_duration(t0.elapsed()),
        pe_time(PeKind::ApplyVocab2, sparse_items, clock),
        "SRAM II=2".into(),
    ]);

    t.note("FPGA column: paper IIs at 250 MHz (sim); CPU column measured on this machine");
    t.print();

    // Runtime-configurable pipelines (paper §5): the same operators
    // recomposed as specs and run through the streaming engine — every
    // spec is validated at planning time (dependency rules included).
    println!();
    let mut t = Table::new(
        "operator specs through the pipeline engine (CPU executor)",
        &["spec", "plans?", "sparse[0][0]", "dense[0][0]"],
    );
    for spec in [
        "modulus:5000 | genvocab | applyvocab | neg2zero | logarithm",
        "modulus:5000 | neg2zero | logarithm", // passthrough sparse
        "modulus:53",                          // bare modulus
        // per-column programs: two vocab sizes + a bucketized column
        "sparse[*]: modulus:5000|genvocab|applyvocab; \
         sparse[0..4]: modulus:100000|genvocab|applyvocab; \
         dense[*]: neg2zero|log; dense[0]: clip:0:100|bucketize:1:10:100",
        "applyvocab | modulus:5000",           // invalid: needs genvocab first
    ] {
        let built = piper::pipeline::PipelineBuilder::new()
            .spec_str(spec)
            .and_then(|b| {
                b.input(piper::accel::InputFormat::Utf8)
                    .schema(ds.schema())
                    .executor(Box::new(piper::cpu_baseline::CpuExecutor::new(
                        piper::cpu_baseline::ConfigKind::I,
                        2,
                    )))
                    .build()
            });
        match built {
            Ok(p) => {
                let mut src = piper::pipeline::MemorySource::new(
                    &raw,
                    piper::accel::InputFormat::Utf8,
                );
                let (cols, _) = p.run_collect(&mut src).expect("planned pipeline runs");
                t.row(&[
                    spec.into(),
                    "yes".into(),
                    cols.sparse[0][0].to_string(),
                    format!("{:.3}", cols.dense[0][0]),
                ]);
            }
            Err(e) => {
                t.row(&[spec.into(), format!("no — {e}"), "-".into(), "-".into()]);
            }
        }
    }
    t.note("invalid compositions are planning errors, not runtime failures");
    t.print();
}
