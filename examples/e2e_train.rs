//! END-TO-END driver: the full three-layer stack on a real small
//! workload, proving all layers compose (DESIGN.md §2):
//!
//!   1. generate a synthetic Criteo-format dataset (rust),
//!   2. preprocess it with the PIPER accelerator simulator (rust, L3),
//!   3. load the AOT-compiled JAX/Pallas DLRM (HLO text → PJRT) and
//!      train for a few hundred steps, logging the loss curve (L2/L1
//!      compute, driven from rust — python is never on this path),
//!   4. report preprocessing-vs-training time (the paper's Fig. 1 gap).
//!
//! Requires `make artifacts` to have produced artifacts/*.hlo.txt.
//!
//!     cargo run --release --example e2e_train [steps] [rows]

#[cfg(not(feature = "pjrt"))]
fn main() -> piper::Result<()> {
    eprintln!("e2e_train: built without the `pjrt` feature — rebuild with --features pjrt");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn main() -> piper::Result<()> {
    use std::path::Path;
    use std::time::Instant;

    use piper::coordinator::{Backend, Experiment};
    use piper::data::{synth::SynthConfig, utf8, SynthDataset};
    use piper::accel::{InputFormat, Mode};
    use piper::ops::Modulus;
    use piper::report::{fmt_duration, Table};
    use piper::runtime::Runtime;
    use piper::train::{train_loop, Trainer};

    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let rows: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8192);

    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("train_step.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // --- 1. data ------------------------------------------------------
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    println!("generated {rows} rows ({} raw bytes)", raw.len());

    // --- 2. preprocessing (PIPER via the pipeline engine) ---------------
    let t0 = Instant::now();
    let backend = Backend::Piper { mode: Mode::Network };
    let exp = Experiment {
        schema: ds.schema(),
        ..Experiment::new(Modulus::VOCAB_5K, InputFormat::Utf8)
    };
    let run = piper::coordinator::run_backend(&backend, &exp, &raw)?;
    let preprocess_meas = t0.elapsed();
    println!(
        "preprocessed {} rows: measured {} on this machine, modeled {} on PIPER [sim]",
        run.rows,
        fmt_duration(preprocess_meas),
        fmt_duration(run.e2e),
    );

    // --- 3. training (PJRT, AOT DLRM) -----------------------------------
    let rt = Runtime::new(&artifacts)?;
    let mut trainer = Trainer::new(&rt, &artifacts)?;
    println!(
        "DLRM loaded: {} params, batch {}, vocab {}",
        trainer.meta.param_count, trainer.meta.batch, trainer.meta.vocab
    );
    let t0 = Instant::now();
    let losses = train_loop(&mut trainer, &run.processed, steps)?;
    let train_meas = t0.elapsed();

    println!("\nloss curve ({} steps):", losses.len());
    let bucket = (losses.len() / 10).max(1);
    for (i, chunk) in losses.chunks(bucket).enumerate() {
        let avg = chunk.iter().sum::<f32>() / chunk.len() as f32;
        let bar = "#".repeat((avg * 60.0).min(60.0) as usize);
        println!("  steps {:>4}+ mean {:.4} {}", i * bucket, avg, bar);
    }
    let first = losses.first().copied().unwrap_or(f32::NAN);
    let last = losses.last().copied().unwrap_or(f32::NAN);
    println!("loss: first {:.4} → last {:.4}", first, last);
    anyhow::ensure!(last.is_finite(), "training diverged");

    // --- 4. the Fig. 1 comparison on this workload ----------------------
    let mut t = Table::new(
        "preprocess vs train (this machine)",
        &["stage", "time", "per row/step"],
    );
    t.row(&[
        "preprocessing (measured, functional sim)".into(),
        fmt_duration(preprocess_meas),
        format!("{:.1} µs/row", preprocess_meas.as_secs_f64() * 1e6 / rows as f64),
    ]);
    t.row(&[
        format!("training ({steps} steps, PJRT CPU)"),
        fmt_duration(train_meas),
        format!("{:.1} ms/step", train_meas.as_secs_f64() * 1e3 / steps as f64),
    ]);
    t.note("paper Fig. 1: preprocessing dominates training — see bench fig1_preproc_vs_train");
    t.print();
    Ok(())
}
