//! Network-attached PIPER over real TCP (paper Fig. 7d on loopback).
//!
//! Spawns a worker on an ephemeral port, streams a synthetic dataset to
//! it under the fused single-pass protocol (the dataset crosses the
//! wire once; appearance indices are assigned on the fly), and collects
//! the preprocessed rows as they stream back — demonstrating that the
//! worker holds only the vocabularies, never the dataset. The sharded
//! cluster below retains the two-pass protocol: its global vocabulary
//! merge is a barrier between the passes.
//!
//!     cargo run --release --example network_serve

use piper::accel::{InputFormat, Mode};
use piper::coordinator::Backend;
use piper::data::{synth::SynthConfig, utf8, SynthDataset};
use piper::net::{leader, protocol::Job, stream::WireFormat};
use piper::ops::{Modulus, PipelineSpec};
use piper::pipeline::{serve_bytes, PipelineBuilder, TcpSource};
use piper::report::{fmt_duration, Table};

fn main() -> piper::Result<()> {
    let rows = 30_000;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    println!("streaming {} rows ({} bytes) to a loopback PIPER worker…", rows, raw.len());

    // The wire handshake carries the full per-column spec; dlrm() is
    // the uniform preset at one vocabulary size.
    let job = Job::dlrm(ds.schema(), Modulus::VOCAB_5K, WireFormat::Utf8);

    let mut t = Table::new(
        "network-attached preprocessing (loopback, fused single pass)",
        &["chunk size", "wallclock [meas]", "rows", "vocab entries"],
    );
    for chunk in [4 * 1024, 64 * 1024, 1024 * 1024] {
        let run = leader::run_loopback(&job, &raw, chunk)?;
        assert_eq!(run.processed.num_rows(), rows);
        t.row(&[
            format!("{} KiB", chunk / 1024),
            fmt_duration(run.wallclock),
            run.stats.rows.to_string(),
            run.stats.vocab_entries.to_string(),
        ]);
    }
    t.note("fused: the dataset crosses the wire ONCE; results stream back mid-pass");
    t.note("worker memory = vocabularies + one chunk; dataset is never resident");
    t.note("paper-scale wire time is modeled at 100 Gbps by accel::network (sim)");
    t.print();

    // Multi-accelerator deployment (paper §3.4.2: scale FPGAs
    // independently): shard across N loopback workers; the single
    // synchronization point is the vocabulary merge between the passes.
    println!();
    let mut t = Table::new(
        "sharded cluster (loopback workers)",
        &["workers", "wallclock [meas]", "rows", "vocab entries"],
    );
    let single = piper::net::run_cluster_loopback(1, &job, &raw, 256 * 1024)?;
    for n in [1usize, 2, 4] {
        let run = piper::net::run_cluster_loopback(n, &job, &raw, 256 * 1024)?;
        assert_eq!(
            run.processed, single.processed,
            "sharding must not change the output"
        );
        t.row(&[
            n.to_string(),
            fmt_duration(run.wallclock),
            run.stats.rows.to_string(),
            run.stats.vocab_entries.to_string(),
        ]);
    }
    t.note("outputs verified identical across cluster sizes (deterministic vocab merge)");
    t.print();

    // The same ingest as a pipeline Source: a remote dataset server
    // streams raw bytes over TCP straight into the engine. The fused
    // plan reads the stream exactly once — one connection, no replay —
    // and nothing is ever resident on the preprocessing side.
    println!();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let payload = raw.clone();
    let server = std::thread::spawn(move || serve_bytes(&listener, &payload, 1));

    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(Modulus::VOCAB_5K.range))
        .input(InputFormat::Utf8)
        .chunk_rows(8192)
        .executor(Backend::Piper { mode: Mode::Network }.executor())
        .build()?;
    let mut source = TcpSource::connect(&addr, InputFormat::Utf8);
    let (cols, report) = pipeline.run_collect(&mut source)?;
    server.join().expect("dataset server panicked")?;
    assert_eq!(cols.num_rows(), rows);
    println!(
        "TcpSource → pipeline engine: {} rows in {} chunks, {} wallclock ({}, {} TCP pass)",
        report.rows,
        report.chunks,
        fmt_duration(report.wall),
        report.strategy.name(),
        report.decode_passes,
    );
    Ok(())
}
