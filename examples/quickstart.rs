//! Quickstart: generate a synthetic Criteo-format dataset, preprocess it
//! with the PIPER simulator, and print what happened.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 30-second tour of the public API: data generation, the
//! accelerator front-end, and the timing report.

use piper::accel::{self, InputFormat, Mode, PiperConfig};
use piper::data::{synth::SynthConfig, utf8, SynthDataset};
use piper::ops::{Modulus, Vocab as _};
use piper::report::{fmt_duration, fmt_rows_per_sec, Table};

fn main() -> piper::Result<()> {
    // 1. A small synthetic dataset in the paper's raw UTF-8 format
    //    (1 label + 13 dense + 26 sparse hex features per row).
    let rows = 20_000;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    println!("dataset: {rows} rows, {} raw bytes\n", raw.len());

    // 2. Preprocess with PIPER in network mode, 5K vocabulary.
    let cfg = PiperConfig::paper(Mode::Network, InputFormat::Utf8, Modulus::VOCAB_5K);
    let run = accel::run(&cfg, &raw)?;

    // 3. What came out: column-major preprocessed features.
    println!(
        "processed {} rows; vocabularies hold {} entries across {} sparse columns",
        run.rows,
        run.vocabs.iter().map(|v| v.len()).sum::<usize>(),
        run.vocabs.len(),
    );
    let r0 = run.processed.row(0);
    println!(
        "row 0 → label {}, dense[0] {:.3}, sparse[0] idx {}\n",
        r0.label, r0.dense[0], r0.sparse[0]
    );

    // 4. The modeled accelerator timing (tagged sim — this machine has no
    //    FPGA; cycles follow the paper's IIs and clocks).
    let mut t = Table::new("PIPER kernel model", &["quantity", "value"]);
    t.row(&["clock".into(), format!("{:.0} MHz", run.kernel.clock_hz / 1e6)]);
    t.row(&["loop 1 bottleneck".into(), run.kernel.loop1_bottleneck.into()]);
    t.row(&["loop 2 bottleneck".into(), run.kernel.loop2_bottleneck.into()]);
    t.row(&[
        "cycles/row (loop1+loop2)".into(),
        format!("{:.1}", run.kernel.loop1_cpr + run.kernel.loop2_cpr),
    ]);
    t.row(&["kernel time [sim]".into(), fmt_duration(run.kernel.seconds())]);
    t.row(&["kernel rows/s [sim]".into(), fmt_rows_per_sec(run.kernel_rows_per_sec())]);
    t.print();
    Ok(())
}
