//! Quickstart: build ONE streaming pipeline, run it over different
//! sources and executors, and print what happened.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 30-second tour of the public API: the `PipelineBuilder`
//! (plan once), `Source`s (in-memory, file, synthetic), `Executor`s
//! (CPU baseline / GPU model / PIPER), and the uniform `RunReport`.

use piper::accel::{InputFormat, Mode};
use piper::coordinator::Backend;
use piper::cpu_baseline::ConfigKind;
use piper::data::{synth::SynthConfig, utf8, SynthDataset};
use piper::ops::PipelineSpec;
use piper::pipeline::{FileSource, MemorySource, PipelineBuilder, SynthSource};
use piper::report::{fmt_duration, fmt_rows_per_sec, fmt_tagged, Table};

fn main() -> piper::Result<()> {
    // 1. A small synthetic dataset in the paper's raw UTF-8 format
    //    (1 label + 13 dense + 26 sparse hex features per row).
    let rows = 20_000;
    let ds = SynthDataset::generate(SynthConfig::small(rows));
    let raw = utf8::encode_dataset(&ds);
    println!("dataset: {rows} rows, {} raw bytes\n", raw.len());

    // 2. Plan pipelines ONCE — the paper's DLRM operator graph at a 5K
    //    vocabulary, chunked execution. Capability mismatches (e.g. a
    //    binary-only CPU config on UTF-8 input) fail here, at planning.
    let backends = [
        Backend::Cpu { kind: ConfigKind::I, threads: 4 },
        Backend::Gpu,
        Backend::Piper { mode: Mode::Network },
    ];
    let mut t = Table::new(
        "one spec, three executors (streamed in 4096-row chunks)",
        &["executor", "rows", "vocab entries", "e2e", "rows/s"],
    );
    let mut reference = None;
    for backend in &backends {
        let pipeline = PipelineBuilder::new()
            .spec(PipelineSpec::dlrm(5_000))
            .input(InputFormat::Utf8)
            .chunk_rows(4096)
            .executor(backend.executor())
            .build()?;
        let mut source = MemorySource::new(&raw, InputFormat::Utf8);
        let (columns, report) = pipeline.run_collect(&mut source)?;
        // Every executor shares the functional core: outputs are
        // bit-identical across platforms.
        let expect = reference.get_or_insert_with(|| columns.clone());
        assert_eq!(expect, &columns, "{} diverged", report.executor);
        t.row(&[
            report.executor.clone(),
            report.rows.to_string(),
            report.vocab_entries.to_string(),
            fmt_tagged(report.e2e, report.tag),
            fmt_rows_per_sec(report.e2e_rows_per_sec()),
        ]);
    }
    t.note("sim-tagged rows model paper hardware; meas rows ran on this machine");
    t.print();
    println!();

    // 3. Pipeline reuse across sources: the same built pipeline serves a
    //    file-backed submission (bounded memory — resident input is one
    //    chunk) and a generator-backed one, with no replanning.
    let pipeline = PipelineBuilder::new()
        .spec(PipelineSpec::dlrm(5_000))
        .input(InputFormat::Utf8)
        .chunk_rows(2048)
        .executor(Backend::Piper { mode: Mode::Network }.executor())
        .build()?;

    let path = std::env::temp_dir().join("piper-quickstart.txt");
    std::fs::write(&path, &raw)?;
    let mut file_src = FileSource::open(&path, InputFormat::Utf8)?;
    let (file_cols, file_report) = pipeline.run_collect(&mut file_src)?;

    let mut synth_src = SynthSource::new(SynthConfig::small(rows), InputFormat::Utf8);
    let (synth_cols, synth_report) = pipeline.run_collect(&mut synth_src)?;
    std::fs::remove_file(&path).ok();

    assert_eq!(file_cols, synth_cols, "same rows → same output, any source");
    let mut t = Table::new(
        "one pipeline, two sources (built once, submitted twice)",
        &["source", "chunks", "rows", "wallclock [meas]", "modeled e2e"],
    );
    for (name, rep) in [("file", &file_report), ("synth generator", &synth_report)] {
        t.row(&[
            name.into(),
            rep.chunks.to_string(),
            rep.rows.to_string(),
            fmt_duration(rep.wall),
            fmt_tagged(rep.e2e, rep.tag),
        ]);
    }
    t.note("file submissions hold one chunk in memory — never the dataset");
    t.print();

    // 4. A custom operator spec (paper §5: operators are runtime-
    //    configurable): drop the logarithm, keep everything else.
    let no_log = PipelineBuilder::new()
        .spec_str("decode | fillmissing | hex2int | modulus:5000 | genvocab | applyvocab | neg2zero")?
        .input(InputFormat::Utf8)
        .executor(Backend::Cpu { kind: ConfigKind::I, threads: 2 }.executor())
        .build()?;
    let (cols, _) = no_log.run_collect(&mut MemorySource::new(&raw, InputFormat::Utf8))?;
    println!(
        "\ncustom spec (no logarithm): dense[0][0] = {} (raw count, not log-scaled)",
        cols.dense[0][0]
    );
    Ok(())
}
