#!/usr/bin/env sh
# Snapshot the pipeline_engine bench's machine-readable outputs at the
# repo root:
#   BENCH_pr4.json  — the decode-threads sweep (PR 4)
#   BENCH_pr5.json  — uniform vs heterogeneous per-column programs (PR 5)
#   BENCH_pr8.json  — stage-pipeline overlap grid (PR 8)
#   BENCH_pr9.json  — containment policy overhead on clean input (PR 9)
#   BENCH_pr10.json — service scale-out sweep over loopback workers (PR 10)
#
# The bench checksum-verifies every point before timing it.
# Usage: scripts/bench_snapshot.sh [rows] [reps]
set -eu

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
ROWS="${1:-200000}"
REPS="${2:-5}"
OUT4="$ROOT/BENCH_pr4.json"
OUT5="$ROOT/BENCH_pr5.json"
OUT8="$ROOT/BENCH_pr8.json"
OUT9="$ROOT/BENCH_pr9.json"
OUT10="$ROOT/BENCH_pr10.json"

echo "pipeline_engine snapshot: $ROWS rows, $REPS reps -> $OUT4, $OUT5, $OUT8, $OUT9, $OUT10"
cd "$ROOT/rust"
PIPER_BENCH_ROWS="$ROWS" PIPER_BENCH_REPS="$REPS" \
    BENCH_JSON="$OUT4" BENCH_PR5_JSON="$OUT5" BENCH_PR8_JSON="$OUT8" \
    BENCH_PR9_JSON="$OUT9" BENCH_PR10_JSON="$OUT10" \
    cargo bench --bench pipeline_engine

echo "snapshots written:"
cat "$OUT4"
cat "$OUT5"
cat "$OUT8"
cat "$OUT9"
cat "$OUT10"
