#!/usr/bin/env sh
# Snapshot the decode-threads sweep into BENCH_pr4.json at the repo root.
#
# Runs the pipeline_engine bench (which checksum-verifies every sweep
# point before timing it) with BENCH_JSON pointed at the snapshot file.
# Usage: scripts/bench_snapshot.sh [rows] [reps]
set -eu

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
ROWS="${1:-200000}"
REPS="${2:-5}"
OUT="$ROOT/BENCH_pr4.json"

echo "decode sweep: $ROWS rows, $REPS reps -> $OUT"
cd "$ROOT/rust"
PIPER_BENCH_ROWS="$ROWS" PIPER_BENCH_REPS="$REPS" BENCH_JSON="$OUT" \
    cargo bench --bench pipeline_engine

echo "snapshot written:"
cat "$OUT"
