#!/usr/bin/env sh
# Guard the committed perf tentpoles against regressions:
#   BENCH_pr4.json  — decode-threads sweep (row-sharded SWAR decode)
#   BENCH_pr5.json  — uniform vs heterogeneous per-column programs
#   BENCH_pr8.json  — stage-pipeline overlap grid (pipelined fused)
#   BENCH_pr9.json  — error-containment policy overhead on clean input
#   BENCH_pr10.json — service scale-out sweep (shard-owned vocabularies)
#
# Runs the pipeline_engine bench fresh, then compares *machine-portable
# ratios* against the committed baselines — decode thread-scaling
# (max-threads vs 1), per-program relative throughput, and the
# stage-pipeline speedups (pipelined vs depth-1 fused, pipelined vs
# two-pass) plus its overlap efficiency and the service scale-out
# speedup (4 loopback workers vs 1) — not absolute rows/s, which
# would just measure the CI runner. A ratio drop larger than THRESHOLD
# (default 25%) fails the script.
#
# The PR 9 gate is different in kind: it is an absolute bound on the
# *current* run, not a drop-vs-baseline check. On clean input the
# skip/fail policies must stay within OVERHEAD_PCT (default 2%) of the
# legacy zero policy's throughput, and quarantine within
# QUARANTINE_OVERHEAD_PCT (default 10%) — the containment machinery is
# only allowed to cost something when a row is actually contained.
#
# Usage: scripts/bench_compare.sh [--bless]
#   --bless     overwrite the baselines with this machine's fresh run
#   THRESHOLD   max tolerated ratio drop in percent (default 25)
#   OVERHEAD_PCT / QUARANTINE_OVERHEAD_PCT  clean-input policy overhead
#               bounds in percent (default 2 / 10)
#   PIPER_BENCH_ROWS / PIPER_BENCH_REPS   forwarded to the bench
#
# Exit codes: 0 = within threshold (or blessed), 1 = perf regression,
# 2 = setup error (baseline missing or unparsable) — so CI can tell a
# real regression from a broken gate.
set -eu

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
ROWS="${PIPER_BENCH_ROWS:-200000}"
REPS="${PIPER_BENCH_REPS:-5}"
THRESHOLD="${THRESHOLD:-25}"
OVERHEAD_PCT="${OVERHEAD_PCT:-2}"
QUARANTINE_OVERHEAD_PCT="${QUARANTINE_OVERHEAD_PCT:-10}"
BASE4="$ROOT/BENCH_pr4.json"
BASE5="$ROOT/BENCH_pr5.json"
BASE8="$ROOT/BENCH_pr8.json"
BASE9="$ROOT/BENCH_pr9.json"
BASE10="$ROOT/BENCH_pr10.json"

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT
CUR4="$TMP/pr4.json"
CUR5="$TMP/pr5.json"
CUR8="$TMP/pr8.json"
CUR9="$TMP/pr9.json"
CUR10="$TMP/pr10.json"

echo "bench_compare: running pipeline_engine ($ROWS rows, $REPS reps)"
cd "$ROOT/rust"
PIPER_BENCH_ROWS="$ROWS" PIPER_BENCH_REPS="$REPS" \
    BENCH_JSON="$CUR4" BENCH_PR5_JSON="$CUR5" BENCH_PR8_JSON="$CUR8" \
    BENCH_PR9_JSON="$CUR9" BENCH_PR10_JSON="$CUR10" \
    cargo bench --bench pipeline_engine >/dev/null

if [ "${1:-}" = "--bless" ]; then
    cp "$CUR4" "$BASE4"
    cp "$CUR5" "$BASE5"
    cp "$CUR8" "$BASE8"
    cp "$CUR9" "$BASE9"
    cp "$CUR10" "$BASE10"
    echo "bench_compare: baselines blessed -> $BASE4, $BASE5, $BASE8, $BASE9, $BASE10"
    exit 0
fi

# A missing baseline is a setup error, never a silent pass (or a silent
# bless of whatever this machine happens to produce).
for base in "$BASE4" "$BASE5" "$BASE8" "$BASE9" "$BASE10"; do
    if [ ! -f "$base" ]; then
        echo "bench_compare: ERROR: baseline $base is missing." >&2
        echo "  Run 'scripts/bench_compare.sh --bless' on a reference machine" >&2
        echo "  and commit the refreshed BENCH_*.json baselines." >&2
        exit 2
    fi
done

python3 - "$BASE4" "$CUR4" "$BASE5" "$CUR5" "$BASE8" "$CUR8" "$BASE9" "$CUR9" \
    "$BASE10" "$CUR10" \
    "$THRESHOLD" "$OVERHEAD_PCT" "$QUARANTINE_OVERHEAD_PCT" <<'EOF'
import json
import sys

docs = []
for path in sys.argv[1:11]:
    try:
        with open(path) as f:
            docs.append(json.load(f))
    except (OSError, ValueError) as e:
        print(f"bench_compare: ERROR: {path} is missing or not valid JSON ({e}).",
              file=sys.stderr)
        print("  Re-bless the baselines with 'scripts/bench_compare.sh --bless' "
              "and commit them.", file=sys.stderr)
        sys.exit(2)
base4, cur4, base5, cur5, base8, cur8, base9, cur9, base10, cur10 = docs
threshold = float(sys.argv[11])
overhead_pct = float(sys.argv[12])
quarantine_overhead_pct = float(sys.argv[13])
failures = []


def ratio_check(name, base_ratio, cur_ratio):
    drop = (1.0 - cur_ratio / base_ratio) * 100.0 if base_ratio > 0 else 0.0
    status = "FAIL" if drop > threshold else "  ok"
    print(f"{status}  {name}: baseline {base_ratio:.2f}x, current {cur_ratio:.2f}x "
          f"(drop {drop:+.1f}%)")
    if drop > threshold:
        failures.append(name)


def decode_scaling(doc):
    rps = {p["decode_threads"]: p["decode_rows_per_s"] for p in doc["sweep"]}
    return rps[max(rps)] / rps[1]


def program_rps(doc):
    return {p["program"]: p["rows_per_s"] for p in doc["programs"]}


def policy_rps(doc):
    return {p["policy"]: p["rows_per_s"] for p in doc["policies"]}


def overhead_check(name, rps, bound_pct):
    """Absolute bound on the current run: `name`'s clean-input overhead
    vs the zero policy must stay under bound_pct percent."""
    overhead = (1.0 - rps[name] / rps["zero"]) * 100.0
    status = "FAIL" if overhead > bound_pct else "  ok"
    print(f"{status}  {name} vs zero on clean input: "
          f"overhead {overhead:+.1f}% (bound {bound_pct:.0f}%)")
    if overhead > bound_pct:
        failures.append(f"{name} clean-input overhead")


def scaleout_speedup(doc):
    """4-loopback-worker speedup over 1 worker (wall-clock ratio)."""
    walls = {p["workers"]: p["wall_s"] for p in doc["sweep"]}
    return walls[1] / walls[max(walls)]


def overlap_ratios(doc):
    """(pipelined-vs-depth1 speedup, pipelined-vs-two-pass speedup,
    overlap efficiency) at the widest decode frontend in the grid."""
    cells = doc["grid"]
    widest = max(c["decode_threads"] for c in cells)
    at = [c for c in cells if c["decode_threads"] == widest]
    d1 = next(c["wall_s"] for c in at if c["pipeline_depth"] == 1)
    best = min(c["wall_s"] for c in at if c["pipeline_depth"] > 1)
    two = doc["two_pass"]["wall_s"]
    return d1 / best, two / best, doc["overlap"]["efficiency"]


try:
    print("decode-threads sweep (PR 4):")
    ratio_check("decode scaling, max threads vs 1",
                decode_scaling(base4), decode_scaling(cur4))
    print("per-column programs (PR 5):")
    b, c = program_rps(base5), program_rps(cur5)
    b8, c8 = overlap_ratios(base8), overlap_ratios(cur8)
    p9 = policy_rps(cur9)
    # Baseline participates only as a shape check; the PR 9 gate below is
    # an absolute bound on the current run, not a drop-vs-baseline.
    policy_rps(base9)
    for want in ("zero", "fail", "skip", "quarantine"):
        if want not in p9:
            raise KeyError(f"policy {want!r} missing from the pr9 run")
    b10, c10 = scaleout_speedup(base10), scaleout_speedup(cur10)
    # The committed reference must actually demonstrate the scale-out
    # claim: >1.5x at 4 loopback workers on the reference machine.
    if b10 <= 1.5:
        raise ValueError(
            f"pr10 baseline speedup4 is {b10:.2f}x; the committed snapshot "
            "must show >1.5x at 4 loopback workers"
        )
except (KeyError, TypeError, StopIteration, ValueError) as e:
    print(f"bench_compare: ERROR: baseline/current JSON has an unexpected shape ({e!r}).",
          file=sys.stderr)
    print("  Re-bless the baselines with 'scripts/bench_compare.sh --bless' "
          "and commit them.", file=sys.stderr)
    sys.exit(2)
uniform = next(iter(b))
for name in b:
    if name not in c:
        failures.append(f"{name} missing from the current run")
        continue
    ratio_check(f"{name} vs {uniform}", b[name] / b[uniform], c[name] / c[uniform])
print("stage-pipeline overlap (PR 8):")
ratio_check("pipelined vs depth-1 fused", b8[0], c8[0])
ratio_check("pipelined vs two-pass", b8[1], c8[1])
ratio_check("overlap efficiency vs ideal stage wall", b8[2], c8[2])
print("containment policy overhead on clean input (PR 9):")
overhead_check("fail", p9, overhead_pct)
overhead_check("skip", p9, overhead_pct)
overhead_check("quarantine", p9, quarantine_overhead_pct)
print("service scale-out (PR 10):")
ratio_check("4 loopback workers vs 1", b10, c10)

if failures:
    print("bench_compare: gate failures: " + ", ".join(failures))
    sys.exit(1)
print(f"bench_compare: all ratios within {threshold}% of baseline "
      f"and clean-input policy overhead within bounds")
EOF
